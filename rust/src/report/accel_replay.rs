//! E13: accel-model trace replay — feed a recorded engine trace into the
//! accelerator simulator and project paper-scale speedup.
//!
//! Two sources for the speculation histogram:
//!
//! * `--trace-in FILE`: a Chrome trace-event JSON export (from
//!   `--trace-out` or `GET /debug/trace`).  The `cat:"spec"` / `name:"iter"`
//!   instants carry drafted/accepted/early-exit per draft→verify round —
//!   exactly the statistics [`Accel::run_trace`] consumes — so a serving
//!   run on one machine can be replayed through the hardware model on
//!   another, with no checkpoint or prompt set.
//! * no `--trace-in`: record live.  Each builtin-zoo model runs once with
//!   tracing armed; the trace rebuilt from the exported JSON must agree
//!   with the engine's own [`SpecTrace`] (a roundtrip self-check of the
//!   recorder + exporter + parser), and is then projected.
//!
//! The CI gate: projected SPEQ speedup vs FP16 must land in (1.0, 5.0) —
//! speculation must help, and the model must not claim absurd wins.
//!
//! [`Accel::run_trace`]: crate::accel::Accel::run_trace

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::accel::{paper_dims, speedup_vs_fp16, Accel, BaselineKind};
use crate::runtime::{load_backend_with, ModelSource, NativeConfig};
use crate::specdec::{Engine, IterRecord, SpecConfig, SpecTrace};
use crate::util::json::Value;

/// Builtin-zoo models projected by default (`--models` overrides).
const DEFAULT_MODELS: [&str; 2] = ["vicuna-7b-tiny", "llama3.2-3b-tiny"];

const PROMPT: &[u8] = b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ";

/// Projected speedup must stay inside this open interval for the gate.
pub const SPEEDUP_GATE: (f64, f64) = (1.0, 5.0);

/// Rebuild a [`SpecTrace`] from the `cat:"spec"` / `name:"iter"` instants
/// of a Chrome trace-event JSON document.  Every other event category is
/// ignored, so a full engine trace (spans, scheduler steps, request
/// lifecycles) parses cleanly.
pub fn spec_trace_from_chrome_json(text: &str) -> Result<SpecTrace> {
    let doc = crate::util::json::parse(text)
        .map_err(|e| anyhow::anyhow!("trace JSON parse error: {e}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(Value::as_arr)
        .context("trace JSON has no traceEvents array")?;
    let mut trace = SpecTrace { iterations: Vec::new(), produced: 0, prompt_len: 1024 };
    for ev in events {
        if ev.get("cat").and_then(Value::as_str) != Some("spec")
            || ev.get("name").and_then(Value::as_str) != Some("iter")
        {
            continue;
        }
        let args = ev.get("args").context("spec iter event without args")?;
        let field = |k: &str| args.get(k).and_then(Value::as_f64).unwrap_or(0.0);
        let accepted = field("accepted") as u32;
        trace.iterations.push(IterRecord {
            drafted: field("drafted") as u32,
            accepted,
            early_exit: field("early_exit") != 0.0,
        });
        // Accepted drafts + the bonus/correction token, mirroring the engine.
        trace.produced += accepted as usize + 1;
    }
    Ok(trace)
}

/// Run E13; the returned JSON mirrors the printed table.
pub fn run_accel_replay(
    native: &NativeConfig,
    gen_len: usize,
    models: &[String],
    trace_in: Option<&Path>,
) -> Result<Value> {
    let names: Vec<String> = if models.is_empty() {
        DEFAULT_MODELS.iter().map(|s| s.to_string()).collect()
    } else {
        models.to_vec()
    };
    let accel = Accel::default();
    let mut out = BTreeMap::new();

    // One recorded file can be projected at several paper-scale dims; a
    // live recording is per model.  `source` tags the BENCH_JSON rows.
    let (file_trace, source) = match trace_in {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading {}", path.display()))?;
            let t = spec_trace_from_chrome_json(&text)?;
            anyhow::ensure!(
                !t.iterations.is_empty(),
                "{} holds no spec/iter events — was the recording armed?",
                path.display()
            );
            println!(
                "\n== E13: accel replay of {} ({} spec iterations) ==",
                path.display(),
                t.iterations.len()
            );
            (Some(t), "file")
        }
        None => {
            println!("\n== E13: accel replay (live recording, builtin zoo, gen_len {gen_len}) ==");
            (None, "engine")
        }
    };

    println!(
        "{:<18} {:>6} {:>8} {:>10} {:>11} {:>12}",
        "model", "iters", "r", "vs FP16", "vs Olive-8b", "vs Tender-8b"
    );
    for name in &names {
        let dims = paper_dims(name)
            .ok_or_else(|| anyhow::anyhow!("no paper dims for {name}"))?;
        let trace = match &file_trace {
            Some(t) => t.clone(),
            None => {
                // Live mode: record this run, then prove the exported JSON
                // reconstructs the engine's own accounting bit-for-bit.
                let backend = load_backend_with(&ModelSource::Builtin, name, native)?;
                let engine = Engine::new(backend.as_ref());
                crate::trace::arm();
                crate::trace::clear();
                let spec = engine
                    .generate_spec(PROMPT, &SpecConfig { max_draft: 16, gen_len, ..Default::default() })?;
                let rebuilt = spec_trace_from_chrome_json(&crate::trace::export_json(usize::MAX))?;
                crate::trace::disarm();
                anyhow::ensure!(
                    rebuilt.iterations == spec.trace.iterations,
                    "trace roundtrip mismatch on {name}: engine recorded {} iterations, \
                     export rebuilt {}",
                    spec.trace.iterations.len(),
                    rebuilt.iterations.len()
                );
                rebuilt
            }
        };
        let speedups: Vec<f64> = [BaselineKind::Speq, BaselineKind::Olive8, BaselineKind::Tender8]
            .iter()
            .map(|&k| speedup_vs_fp16(k, &accel, dims, 1024, Some(&trace)))
            .collect();
        let (speq, olive, tender) = (speedups[0], speedups[1], speedups[2]);
        let r = trace.accept_rate();
        println!(
            "{name:<18} {:>6} {r:>8.3} {:>9.2}x {:>10.2}x {:>11.2}x",
            trace.iterations.len(),
            speq,
            speq / olive,
            speq / tender
        );
        println!(
            "BENCH_JSON {{\"group\":\"report_accel_replay\",\"model\":\"{name}\",\"source\":\"{source}\",\"iters\":{},\"accept_rate\":{:.4},\"speedup_vs_fp16\":{:.4},\"speedup_vs_olive8\":{:.4},\"speedup_vs_tender8\":{:.4}}}",
            trace.iterations.len(),
            r,
            speq,
            speq / olive,
            speq / tender
        );
        anyhow::ensure!(
            speq > SPEEDUP_GATE.0 && speq < SPEEDUP_GATE.1,
            "accel replay on {name}: projected {speq:.2}x vs FP16 outside ({}, {}) — \
             the replayed accept statistics or the hardware model are broken",
            SPEEDUP_GATE.0,
            SPEEDUP_GATE.1
        );
        out.insert(
            name.clone(),
            Value::Obj(
                [
                    ("source".to_string(), Value::Str(source.to_string())),
                    ("iters".to_string(), Value::Num(trace.iterations.len() as f64)),
                    ("accept_rate".to_string(), Value::Num(r)),
                    ("speedup_vs_fp16".to_string(), Value::Num(speq)),
                    ("speedup_vs_olive8".to_string(), Value::Num(speq / olive)),
                    ("speedup_vs_tender8".to_string(), Value::Num(speq / tender)),
                ]
                .into_iter()
                .collect(),
            ),
        );
    }
    println!("(gate: projected SPEQ speedup vs FP16 within (1.0, 5.0); paper: ~2.07x)");
    Ok(Value::Obj(out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rebuilds_spec_trace_from_chrome_json() {
        let text = r#"{"traceEvents":[
            {"name":"step","cat":"sched","ph":"X","ts":1,"dur":5,"pid":1,"tid":1,"args":{"n":2}},
            {"name":"iter","cat":"spec","ph":"i","ts":2,"pid":1,"tid":1,"s":"t",
             "args":{"drafted":8,"accepted":6,"early_exit":0}},
            {"name":"iter","cat":"spec","ph":"i","ts":3,"pid":1,"tid":1,"s":"t",
             "args":{"drafted":4,"accepted":4,"early_exit":1}}
        ]}"#;
        let t = spec_trace_from_chrome_json(text).unwrap();
        assert_eq!(t.iterations.len(), 2);
        assert_eq!(t.iterations[0], IterRecord { drafted: 8, accepted: 6, early_exit: false });
        assert_eq!(t.iterations[1], IterRecord { drafted: 4, accepted: 4, early_exit: true });
        assert_eq!(t.produced, 6 + 1 + 4 + 1);
    }

    #[test]
    fn rejects_documents_without_trace_events() {
        assert!(spec_trace_from_chrome_json("{}").is_err());
        assert!(spec_trace_from_chrome_json("not json").is_err());
    }
}
