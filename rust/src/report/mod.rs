//! The evaluation harness: regenerates every table and figure of the
//! paper's §V (experiment index in DESIGN.md §5).
//!
//! | exp id        | paper artifact                         |
//! |---------------|----------------------------------------|
//! | `fig2c`       | exponent distribution of LLM weights   |
//! | `table1`      | FP4-variant perplexity                 |
//! | `table2`      | draft length & accept rate             |
//! | `table3`      | speedup vs FP16 per model x task       |
//! | `table4`      | area & power breakdown                 |
//! | `fig7`        | speedup vs Olive/Tender                |
//! | `fig8`        | energy efficiency                      |
//! | `fig9`        | L / gamma ablation                     |
//! | `specdec-cmp` | §V-D vs Medusa / Swift                 |
//! | `theory`      | Eq. 1–2 vs simulation (E10)            |
//! | `adaptive`    | static vs adaptive draft length (E12)  |
//! | `accel-replay`| accel-model replay of a recorded trace (E13) |
//!
//! Results print as paper-style tables and persist as JSON under
//! `artifacts/results/` for EXPERIMENTS.md.  `adaptive` and
//! `accel-replay` are special: they run on the builtin zoo and need no
//! artifacts ([`run_adaptive`] / [`run_accel_replay`] are callable
//! standalone; the CLI uses them when no manifest exists).

mod accel_replay;
mod adaptive;
mod context;
mod experiments;
mod perplexity;

pub use accel_replay::{run_accel_replay, spec_trace_from_chrome_json};
pub use adaptive::run_adaptive;
pub use context::{ReportCtx, ReportOpts};
pub use experiments::{run_experiment, EXPERIMENTS};
pub use perplexity::{perplexity, perplexity_with_transform};
