//! The evaluation harness: regenerates every table and figure of the
//! paper's §V (experiment index in DESIGN.md §5).
//!
//! | exp id        | paper artifact                         |
//! |---------------|----------------------------------------|
//! | `fig2c`       | exponent distribution of LLM weights   |
//! | `table1`      | FP4-variant perplexity                 |
//! | `table2`      | draft length & accept rate             |
//! | `table3`      | speedup vs FP16 per model x task       |
//! | `table4`      | area & power breakdown                 |
//! | `fig7`        | speedup vs Olive/Tender                |
//! | `fig8`        | energy efficiency                      |
//! | `fig9`        | L / gamma ablation                     |
//! | `specdec-cmp` | §V-D vs Medusa / Swift                 |
//! | `theory`      | Eq. 1–2 vs simulation (E10)            |
//! | `adaptive`    | static vs adaptive draft length (E12)  |
//!
//! Results print as paper-style tables and persist as JSON under
//! `artifacts/results/` for EXPERIMENTS.md.  `adaptive` is special: it
//! runs on the builtin zoo and needs no artifacts ([`run_adaptive`] is
//! callable standalone; the CLI uses it when no manifest exists).

mod adaptive;
mod context;
mod experiments;
mod perplexity;

pub use adaptive::run_adaptive;
pub use context::{ReportCtx, ReportOpts};
pub use experiments::{run_experiment, EXPERIMENTS};
pub use perplexity::{perplexity, perplexity_with_transform};
