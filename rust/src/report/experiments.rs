//! The per-experiment drivers (E1–E10 in DESIGN.md §5).

use std::collections::BTreeMap;

use anyhow::Result;

use super::context::ReportCtx;
use super::perplexity::{perplexity, perplexity_with_transform};
use crate::accel::{
    paper_dims, power_report, speedup_vs_fp16, table4_area, Accel, BaselineKind,
    DesignPoint, SPECDEC_BASELINES,
};
use crate::bsfp::exponent_histogram;
use crate::quant::transform_weights;
use crate::specdec::{expected_accept_length, SpecTrace};
use crate::util::json::Value;
use crate::workload::{heldout_windows, task_names};

/// All experiment ids, in DESIGN.md order (`traffic` is the measured
/// quarter-to-all weight-stream accounting added with the bit-plane
/// weight store).
pub const EXPERIMENTS: [&str; 13] = [
    "fig2c", "table1", "table2", "table3", "table4", "fig7", "fig8", "fig9",
    "specdec-cmp", "theory", "traffic", "adaptive", "accel-replay",
];

/// Run one experiment (or `all`).
pub fn run_experiment(ctx: &mut ReportCtx, exp: &str) -> Result<()> {
    match exp {
        "all" => {
            for e in EXPERIMENTS {
                run_experiment(ctx, e)?;
            }
            Ok(())
        }
        "fig2c" => fig2c(ctx),
        "table1" => table1(ctx),
        "table2" => table2(ctx),
        "table3" => table3(ctx),
        "table4" => table4(ctx),
        "fig7" => fig7(ctx),
        "fig8" => fig8(ctx),
        "fig9" => fig9(ctx),
        "specdec-cmp" => specdec_cmp(ctx),
        "theory" => theory(ctx),
        "traffic" => traffic(ctx),
        "adaptive" => {
            let v = super::adaptive::run_adaptive(
                &ctx.opts.threads,
                ctx.opts.gen_len,
                &ctx.opts.models,
            )?;
            ctx.save_result("adaptive", &v)
        }
        "accel-replay" => {
            let v = super::accel_replay::run_accel_replay(
                &ctx.opts.threads,
                ctx.opts.gen_len,
                &ctx.opts.models,
                ctx.opts.trace_in.as_deref(),
            )?;
            ctx.save_result("accel_replay", &v)
        }
        other => anyhow::bail!("unknown experiment {other:?} (have {EXPERIMENTS:?} or 'all')"),
    }
}

/// Deterministic trace realizing accept rate ~r at draft length l.
fn synthetic_trace_with_rate(r: f64, l: u32, iters: usize) -> SpecTrace {
    let mut iterations = Vec::new();
    let mut acc = 0.0;
    for _ in 0..iters {
        acc += r * l as f64;
        let accepted = (acc.min(l as f64)) as u32;
        acc -= accepted as f64;
        iterations.push(crate::specdec::IterRecord { drafted: l, accepted, early_exit: false });
    }
    let produced = iterations.iter().map(|i| i.accepted as usize + 1).sum();
    SpecTrace { iterations, produced, prompt_len: 1024 }
}

fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(v: f64) -> Value {
    Value::Num(v)
}

/// E1 / Fig. 2(c): exponent distribution of the trained models' weights.
fn fig2c(ctx: &mut ReportCtx) -> Result<()> {
    println!("\n== Fig. 2(c): FP16 exponent distribution of linear weights ==");
    println!("{:<18} {:>12} {:>12} {:>10} {:>8}", "model", "exp<=15", "exp>=16", "%wasted-bit", "max exp");
    let mut out = BTreeMap::new();
    for name in ctx.model_names() {
        let model = ctx.model(&name)?;
        let mut hist = [0u64; 32];
        for lin in model.linears().to_vec() {
            let h = exponent_histogram(model.weights().f32(&lin).iter().copied());
            for (a, b) in hist.iter_mut().zip(h) {
                *a += b;
            }
        }
        let low: u64 = hist[..16].iter().sum();
        let high: u64 = hist[16..].iter().sum();
        let max_exp = hist.iter().rposition(|&c| c > 0).unwrap_or(0);
        println!(
            "{name:<18} {low:>12} {high:>12} {:>9.3}% {max_exp:>8}",
            100.0 * low as f64 / (low + high) as f64
        );
        out.insert(
            name.clone(),
            obj(vec![
                ("hist", Value::Arr(hist.iter().map(|&c| num(c as f64)).collect())),
                ("low", num(low as f64)),
                ("high", num(high as f64)),
            ]),
        );
    }
    println!("(the paper's premise: exponents confined to [0,15] — the top bit is free)");
    ctx.save_result("fig2c", &Value::Obj(out))
}

/// E2 / Table I: perplexity of the FP4 variants.
fn table1(ctx: &mut ReportCtx) -> Result<()> {
    println!("\n== Table I: draft-model perplexity by quantization variant ==");
    // The paper evaluates 3 models here.
    let models: Vec<String> = ctx
        .model_names()
        .into_iter()
        .filter(|m| ["llama3.1-8b-tiny", "llama2-7b-tiny", "vicuna-7b-tiny"].contains(&m.as_str()))
        .collect();
    let variants = ["fp16", "e1m2", "e2m1", "e3m0", "bsfp"];
    let windows = heldout_windows(&ctx.manifest, 256, ctx.opts.ppl_windows)?;
    println!(
        "{:<10} {}",
        "method",
        models.iter().map(|m| format!("{m:>18}")).collect::<String>()
    );
    let mut rows = BTreeMap::new();
    for variant in variants {
        let mut cells = Vec::new();
        for name in &models {
            let model = ctx.model(name)?;
            let ppl = if variant == "fp16" {
                perplexity(model, &windows)?
            } else {
                perplexity_with_transform(model, &windows, |_, w, k, n| {
                    transform_weights(variant, w, k, n).map_err(|e| anyhow::anyhow!(e))
                })?
            };
            cells.push(ppl);
        }
        let label = match variant {
            "e3m0" => "E3M0/Naive",
            "bsfp" => "+Remap",
            v => v,
        };
        println!(
            "{label:<10} {}",
            cells.iter().map(|p| format!("{p:>18.3}")).collect::<String>()
        );
        rows.insert(
            variant.to_string(),
            Value::Arr(cells.into_iter().map(num).collect()),
        );
    }
    println!("(expect: E1M2 > E2M1 > E3M0 >> +Remap ~ FP16, as in the paper)");
    let mut out = BTreeMap::new();
    out.insert("models".to_string(), Value::Arr(models.into_iter().map(Value::Str).collect()));
    out.insert("ppl".to_string(), Value::Obj(rows));
    ctx.save_result("table1", &Value::Obj(out))
}

/// Shared: collect default-config traces for all (model, task) cells.
fn default_traces(ctx: &mut ReportCtx) -> Result<BTreeMap<(String, String), SpecTrace>> {
    let mut traces = BTreeMap::new();
    for model in ctx.model_names() {
        for task in task_names() {
            let t = ctx.trace_for(&model, task, 16, 0.6)?;
            traces.insert((model.clone(), task.to_string()), t);
        }
    }
    Ok(traces)
}

/// E3 / Table II: average draft length and accept rate.
fn table2(ctx: &mut ReportCtx) -> Result<()> {
    println!("\n== Table II: draft length L-bar and accept rate r (L=16, gamma=0.6) ==");
    let traces = default_traces(ctx)?;
    println!(
        "{:<18} {:>14} {:>14} {:>14} {:>8}",
        "model", "code(HumEval)", "chat(MT-b)", "math(GSM8K)", "mean r"
    );
    let mut out = BTreeMap::new();
    for model in ctx.model_names() {
        let mut cells = Vec::new();
        let mut rs = Vec::new();
        for task in task_names() {
            let t = &traces[&(model.clone(), task.to_string())];
            cells.push(format!("{:>6.2}/{:<6.3}", t.mean_draft_len(), t.accept_rate()));
            rs.push(t.accept_rate());
        }
        let mean_r = rs.iter().sum::<f64>() / rs.len() as f64;
        println!("{model:<18} {} {mean_r:>8.3}", cells.join(" "));
        out.insert(
            model.clone(),
            obj(vec![
                (
                    "per_task",
                    Value::Obj(
                        task_names()
                            .iter()
                            .map(|task| {
                                let t = &traces[&(model.clone(), task.to_string())];
                                (
                                    task.to_string(),
                                    obj(vec![
                                        ("draft_len", num(t.mean_draft_len())),
                                        ("accept_rate", num(t.accept_rate())),
                                        ("accept_len", num(t.mean_accept_len())),
                                    ]),
                                )
                            })
                            .collect(),
                    ),
                ),
                ("mean_r", num(mean_r)),
            ]),
        );
    }
    println!("(format: L-bar/r; paper Table II reports L-bar 4.5-8.4, r 0.95-0.99)");
    ctx.save_result("table2", &Value::Obj(out))
}

/// E4 / Table III: speedup vs FP16, per model x task, at paper-scale dims.
fn table3(ctx: &mut ReportCtx) -> Result<()> {
    println!("\n== Table III: SPEQ speedup over FP16 (accel sim @ paper dims, ctx 1024) ==");
    let traces = default_traces(ctx)?;
    let accel = Accel::default();
    println!(
        "{:<18} {:>10} {:>10} {:>10} {:>8}",
        "model", "code", "chat", "math", "mean"
    );
    let mut out = BTreeMap::new();
    for model in ctx.model_names() {
        let dims = paper_dims(&model)
            .ok_or_else(|| anyhow::anyhow!("no paper dims for {model}"))?;
        let mut speeds = Vec::new();
        for task in task_names() {
            let t = &traces[&(model.clone(), task.to_string())];
            let tc = accel.run_trace(dims, t, 1024);
            speeds.push(tc.speedup());
        }
        let mean = speeds.iter().sum::<f64>() / speeds.len() as f64;
        println!(
            "{model:<18} {:>9.2}x {:>9.2}x {:>9.2}x {:>7.2}x",
            speeds[0], speeds[1], speeds[2], mean
        );
        out.insert(
            model.clone(),
            obj(vec![
                ("code", num(speeds[0])),
                ("chat", num(speeds[1])),
                ("math", num(speeds[2])),
                ("mean", num(mean)),
            ]),
        );
    }
    println!("(paper Table III: 1.93x-2.21x, mean 2.08x)");
    ctx.save_result("table3", &Value::Obj(out))
}

/// E5 / Table IV: area and power breakdown.
fn table4(ctx: &mut ReportCtx) -> Result<()> {
    println!("\n== Table IV: area & power breakdown @ 500 MHz (28 nm model) ==");
    let accel = Accel::default();
    let q = power_report(&accel.cfg, &accel.energy, true);
    let f = power_report(&accel.cfg, &accel.energy, false);
    println!(
        "{:<10} {:>8} {:>22} {:>18}",
        "module", "area", "power (quantize mode)", "power (full mode)"
    );
    let area = table4_area();
    let rows = [
        ("PE", q.pe_pct, f.pe_pct),
        ("Decoder", q.decoder_pct, f.decoder_pct),
        ("SRAM", q.sram_pct, f.sram_pct),
        ("VPU", q.vpu_pct, f.vpu_pct),
        ("Others", q.others_pct, f.others_pct),
    ];
    for (i, (name, qp, fp)) in rows.iter().enumerate() {
        let area_pct = 100.0 * area[i].1 / 6.3;
        println!("{name:<10} {area_pct:>7.1}% {qp:>21.1}% {fp:>17.1}%");
    }
    println!(
        "{:<10} {:>7.1}mm2 {:>20.0}mW {:>16.0}mW",
        "Total", 6.3, q.total_mw, f.total_mw
    );
    println!("(paper: 6.3 mm2; 508 mW quantize / 559 mW full)");
    let out = obj(vec![
        ("total_area_mm2", num(6.3)),
        ("quant_mw", num(q.total_mw)),
        ("full_mw", num(f.total_mw)),
        ("quant_pe_pct", num(q.pe_pct)),
        ("quant_decoder_pct", num(q.decoder_pct)),
        ("quant_sram_pct", num(q.sram_pct)),
        ("full_pe_pct", num(f.pe_pct)),
        ("full_decoder_pct", num(f.decoder_pct)),
    ]);
    ctx.save_result("table4", &out)
}

/// E6 / Fig. 7: speedup vs the quantization accelerators.
fn fig7(ctx: &mut ReportCtx) -> Result<()> {
    println!("\n== Fig. 7: decoding speedup vs FP16 / Olive / Tender ==");
    let traces = default_traces(ctx)?;
    let accel = Accel::default();
    let designs = [
        BaselineKind::Fp16,
        BaselineKind::Olive8,
        BaselineKind::Tender8,
        BaselineKind::Olive4,
        BaselineKind::Tender4,
        BaselineKind::Speq,
    ];
    println!(
        "{:<18} {:>7} {:>9} {:>10} {:>9} {:>10} {:>7}",
        "model", "FP16", "Olive-8b", "Tender-8b", "Olive-4b*", "Tender-4b*", "SPEQ"
    );
    let mut out = BTreeMap::new();
    let mut sums = vec![0.0f64; designs.len()];
    let names = ctx.model_names();
    for model in &names {
        let dims = paper_dims(model)
            .ok_or_else(|| anyhow::anyhow!("no paper dims for {model}"))?;
        // SPEQ uses the mean of the three tasks (paper's methodology).
        let mut merged = SpecTrace::default();
        for task in task_names() {
            merged.merge(&traces[&(model.clone(), task.to_string())]);
        }
        let mut row = Vec::new();
        for (i, kind) in designs.iter().enumerate() {
            let s = speedup_vs_fp16(*kind, &accel, dims, 1024, Some(&merged));
            sums[i] += s;
            row.push(s);
        }
        println!(
            "{model:<18} {:>6.2}x {:>8.2}x {:>9.2}x {:>8.2}x {:>9.2}x {:>6.2}x",
            row[0], row[1], row[2], row[3], row[4], row[5]
        );
        out.insert(
            model.clone(),
            Value::Arr(row.into_iter().map(num).collect()),
        );
    }
    let n = names.len() as f64;
    println!(
        "{:<18} {:>6.2}x {:>8.2}x {:>9.2}x {:>8.2}x {:>9.2}x {:>6.2}x",
        "mean",
        sums[0] / n,
        sums[1] / n,
        sums[2] / n,
        sums[3] / n,
        sums[4] / n,
        sums[5] / n
    );
    let speq = sums[5] / n;
    println!(
        "SPEQ vs FP16 {:.2}x | vs Olive-8b {:.2}x | vs Tender-8b {:.2}x   (* = lossy designs)",
        speq / (sums[0] / n),
        speq / (sums[1] / n),
        speq / (sums[2] / n)
    );
    // Hardware-model validation at the paper's measured operating point
    // (r = 0.976, L-bar ~ 6.4 with early exit): replaying a synthetic trace
    // with the paper's accept statistics isolates the accelerator model
    // from the tiny-testbed accept rates.
    let paper_trace = synthetic_trace_with_rate(0.976, 16, 64);
    let mut cal = 0.0;
    for model in &names {
        let dims = paper_dims(model).unwrap();
        cal += accel.run_trace(dims, &paper_trace, 1024).speedup();
    }
    println!(
        "SPEQ @ paper operating point (r=0.976, L=16): {:.2}x vs FP16 (paper: 2.07x)",
        cal / n
    );
    println!("(paper: 2.07x vs FP16, 1.53x vs Olive-8b, 1.45x vs Tender-8b; ~parity with Olive-4b)");
    out.insert(
        "designs".to_string(),
        Value::Arr(designs.iter().map(|d| Value::Str(format!("{d:?}"))).collect()),
    );
    ctx.save_result("fig7", &Value::Obj(out))
}

/// E7 / Fig. 8: energy efficiency vs the baselines.
fn fig8(ctx: &mut ReportCtx) -> Result<()> {
    println!("\n== Fig. 8: energy efficiency (tokens/J, normalized to FP16) ==");
    let traces = default_traces(ctx)?;
    let accel = Accel::default();
    println!(
        "{:<18} {:>7} {:>9} {:>10} {:>7}",
        "model", "FP16", "Olive-8b", "Tender-8b", "SPEQ"
    );
    let mut out = BTreeMap::new();
    let mut sums = [0.0f64; 4];
    let names = ctx.model_names();
    for model in &names {
        let dims = paper_dims(model)
            .ok_or_else(|| anyhow::anyhow!("no paper dims for {model}"))?;
        let mut merged = SpecTrace::default();
        for task in task_names() {
            merged.merge(&traces[&(model.clone(), task.to_string())]);
        }
        let fp16 = DesignPoint::get(BaselineKind::Fp16).token_cost(&accel, dims, 1024);
        let fp16_e = fp16.energy.total_pj();
        let o8 = DesignPoint::get(BaselineKind::Olive8).token_cost(&accel, dims, 1024);
        let t8 = DesignPoint::get(BaselineKind::Tender8).token_cost(&accel, dims, 1024);
        let tc = accel.run_trace(dims, &merged, 1024);
        let speq_per_tok = tc.spec.energy.total_pj() / tc.tokens.max(1) as f64;
        let row = [
            1.0,
            fp16_e / o8.energy.total_pj(),
            fp16_e / t8.energy.total_pj(),
            fp16_e / speq_per_tok,
        ];
        for (s, r) in sums.iter_mut().zip(row) {
            *s += r;
        }
        println!(
            "{model:<18} {:>6.2}x {:>8.2}x {:>9.2}x {:>6.2}x",
            row[0], row[1], row[2], row[3]
        );
        out.insert(model.clone(), Value::Arr(row.iter().map(|&v| num(v)).collect()));
    }
    let n = names.len() as f64;
    println!(
        "{:<18} {:>6.2}x {:>8.2}x {:>9.2}x {:>6.2}x",
        "mean",
        sums[0] / n,
        sums[1] / n,
        sums[2] / n,
        sums[3] / n
    );
    println!("(paper: SPEQ 1.74x vs FP16, 1.35x vs Olive-8b, 1.32x vs Tender-8b)");
    ctx.save_result("fig8", &Value::Obj(out))
}

/// E8 / Fig. 9: L / gamma ablation on the chat task.
fn fig9(ctx: &mut ReportCtx) -> Result<()> {
    println!("\n== Fig. 9: hyperparameter ablation (chat task; accel speedup) ==");
    let ls = [4usize, 8, 12, 16, 20];
    let gammas = [0.0f32, 0.2, 0.4, 0.6, 0.8];
    let accel = Accel::default();
    let mut out = BTreeMap::new();
    let models: Vec<String> = ctx
        .model_names()
        .into_iter()
        .filter(|m| ["llama3.1-8b-tiny", "vicuna-7b-tiny"].contains(&m.as_str()))
        .collect();
    for model in &models {
        let dims = paper_dims(model)
            .ok_or_else(|| anyhow::anyhow!("no paper dims for {model}"))?;
        println!("\n  {model} (rows = L, cols = gamma {gammas:?})");
        let mut grid = Vec::new();
        for &l in &ls {
            let mut row = Vec::new();
            for &g in &gammas {
                let t = ctx.trace_for(model, "chat", l, g)?;
                let s = accel.run_trace(dims, &t, 1024).speedup();
                row.push(s);
            }
            println!(
                "  L={l:<3} {}",
                row.iter().map(|s| format!("{s:>7.2}x")).collect::<String>()
            );
            grid.push(Value::Arr(row.into_iter().map(num).collect()));
        }
        out.insert(model.clone(), Value::Arr(grid));
    }
    println!("(square = default L=16, gamma=0.6; paper: default within ~5% of optimum)");
    out.insert("ls".into(), Value::Arr(ls.iter().map(|&l| num(l as f64)).collect()));
    out.insert(
        "gammas".into(),
        Value::Arr(gammas.iter().map(|&g| num(g as f64)).collect()),
    );
    ctx.save_result("fig9", &Value::Obj(out))
}

/// E9 / §V-D: comparison with other speculative decoding methods.
fn specdec_cmp(ctx: &mut ReportCtx) -> Result<()> {
    println!("\n== §V-D: SPEQ vs Medusa / Swift (Vicuna-7b, chat/MT-bench) ==");
    let model = "vicuna-7b-tiny".to_string();
    let t = ctx.trace_for(&model, "chat", 16, 0.6)?;
    let dims = paper_dims(&model).unwrap();
    let accel = Accel::default();
    let speq = accel.run_trace(dims, &t, 1024).speedup();
    println!(
        "{:<10} {:>9} {:>10} {:>12} {:>12}",
        "method", "speedup", "vs SPEQ", "training?", "extra mem"
    );
    println!("{:<10} {speq:>8.2}x {:>10} {:>12} {:>12}", "SPEQ", "1.00x", "no", "0%");
    let mut out = BTreeMap::new();
    out.insert("SPEQ".to_string(), num(speq));
    for b in &SPECDEC_BASELINES {
        let s = b.speedup();
        println!(
            "{:<10} {s:>8.2}x {:>9.2}x {:>12} {:>11.0}%",
            b.name,
            speq / s,
            if b.needs_training { "yes" } else { "no" },
            b.memory_overhead * 100.0
        );
        out.insert(b.name.to_string(), num(s));
    }
    println!("(paper: SPEQ 2.03x, surpassing Swift by 1.52x and Medusa by 1.05x)");
    ctx.save_result("specdec_cmp", &Value::Obj(out))
}

/// E10: validate Eq. 1–2 against the simulated traces.
fn theory(ctx: &mut ReportCtx) -> Result<()> {
    println!("\n== E10: Eq. 1-2 analytic model vs measured traces ==");
    let traces = default_traces(ctx)?;
    let accel = Accel::default();
    println!(
        "{:<18} {:<6} {:>7} {:>9} {:>9} {:>10} {:>10}",
        "model", "task", "r", "La(eq1)", "La(meas)", "S(eq2)", "S(sim)"
    );
    let mut out = Vec::new();
    for model in ctx.model_names() {
        let dims = paper_dims(&model).unwrap();
        for task in task_names() {
            let t = &traces[&(model.clone(), task.to_string())];
            let r = t.accept_rate();
            // Eq. 1-2 assume drafting always runs to L; with early exit the
            // effective draft length is L-bar, so the analytic model is
            // evaluated there (the paper's equations, honestly applied).
            let l_eff = t.mean_draft_len().round().max(1.0) as usize;
            let la_pred = expected_accept_length(r, l_eff);
            let la_meas = t.mean_accept_len();
            // Eq. 2 with the simulator's own cost ratios.
            let td = accel
                .decode_step_cost(dims, 1024, crate::accel::ArrayMode::Quant)
                .cycles as f64;
            let tar = accel
                .decode_step_cost(dims, 1024, crate::accel::ArrayMode::Full)
                .cycles as f64;
            let tv = accel.verify_cost(dims, 1024, l_eff + 1).cycles as f64;
            let s_pred = crate::specdec::theoretical_speedup(r, l_eff, td / tar, tv / tar);
            let s_sim = accel.run_trace(dims, t, 1024).speedup();
            println!(
                "{model:<18} {task:<6} {r:>7.3} {la_pred:>9.2} {la_meas:>9.2} {s_pred:>9.2}x {s_sim:>9.2}x"
            );
            out.push(obj(vec![
                ("model", Value::Str(model.clone())),
                ("task", Value::Str(task.to_string())),
                ("r", num(r)),
                ("la_pred", num(la_pred)),
                ("la_meas", num(la_meas)),
                ("s_pred", num(s_pred)),
                ("s_sim", num(s_sim)),
            ]));
        }
    }
    println!("(Eq. 1 assumes geometric acceptance + fixed L; early exit makes measured");
    println!(" La deviate at low r — the gap is the early-exit benefit, E8)");
    ctx.save_result("theory", &Value::Arr(out))
}

/// E11: measured weight traffic per pass — the quarter-to-all ratio as a
/// number, straight from the bit-plane store's [`TrafficCounters`].
///
/// [`TrafficCounters`]: crate::runtime::TrafficCounters
fn traffic(ctx: &mut ReportCtx) -> Result<()> {
    println!("\n== E11: weight bytes streamed per decoded token (quarter-to-all) ==");
    println!(
        "{:<18} {:>13} {:>13} {:>13} {:>8}",
        "model", "draft B/tok", "full B/tok", "verify B/row", "ratio"
    );
    let steps = 4usize;
    let mut out = BTreeMap::new();
    for name in ctx.model_names() {
        let model = ctx.model(&name)?;
        let plen = 8usize.min(model.prefill_len());
        let toks = vec![b' ' as i32; model.prefill_len()];
        let pre = model.prefill(&toks, plen)?;
        model.drain_traffic();
        let mut state = Some(pre.state);
        for i in 0..steps {
            let o = model.decode_draft(1, plen + i, state.take().unwrap())?;
            state = Some(o.state);
        }
        let draft = model.drain_traffic();
        for i in 0..steps {
            let o = model.decode_full(1, plen + steps + i, state.take().unwrap())?;
            state = Some(o.state);
        }
        let full = model.drain_traffic();
        let vtokens: Vec<i32> = vec![0; model.slots()];
        let _ = model.verify(&vtokens, plen + 2 * steps, state.take().unwrap())?;
        let verify = model.drain_traffic();
        let d = draft.draft_bytes_per_token();
        let f = full.full_bytes_per_token();
        let v = verify.verify_bytes_per_row();
        let ratio = if f > 0.0 { d / f } else { 0.0 };
        println!("{name:<18} {d:>13.0} {f:>13.0} {v:>13.0} {ratio:>7.3}x");
        out.insert(
            name.clone(),
            obj(vec![
                ("bytes_per_token_draft", num(d)),
                ("bytes_per_token_full", num(f)),
                ("bytes_per_row_verify", num(v)),
                ("draft_full_ratio", num(ratio)),
            ]),
        );
    }
    println!("(the paper's headline: the draft pass reads a quarter of the weight bits)");
    ctx.save_result("traffic", &Value::Obj(out))
}
