//! Shared experiment context: lazily-loaded models + trace caching.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::model::{Manifest, SamplingParams};
use crate::runtime::{load_backend_with, Backend, ModelSource, NativeConfig};
use crate::specdec::{Engine, SpecConfig, SpecTrace};
use crate::util::json::Value;
use crate::workload::{load_task, load_trace, save_trace, TraceRecord};

/// Experiment sizing knobs (CLI-exposed).
#[derive(Debug, Clone)]
pub struct ReportOpts {
    pub artifacts_root: PathBuf,
    /// Subset of models (empty = all).
    pub models: Vec<String>,
    /// Prompts per (model, task) cell.
    pub n_prompts: usize,
    /// Tokens generated per prompt.
    pub gen_len: usize,
    /// Held-out windows for perplexity.
    pub ppl_windows: usize,
    /// Ignore cached traces.
    pub fresh: bool,
    /// Native kernel worker-pool width (`--threads`; bit-identical results
    /// for every value, so cached traces stay valid across widths).
    pub threads: NativeConfig,
    /// Recorded Chrome trace-event JSON to replay through the accelerator
    /// model (`--trace-in`, `accel-replay` only; `None` = record live).
    pub trace_in: Option<PathBuf>,
}

impl Default for ReportOpts {
    fn default() -> Self {
        Self {
            artifacts_root: Manifest::default_root(),
            models: vec![],
            n_prompts: 4,
            gen_len: 256,
            ppl_windows: 12,
            fresh: false,
            threads: NativeConfig::default(),
            trace_in: None,
        }
    }
}

/// Lazily-loading experiment context.
///
/// The report harness regenerates the paper's tables from *trained*
/// checkpoints, so it requires an artifacts directory; models execute on
/// whatever backend [`load_backend`] selects (native by default).
pub struct ReportCtx {
    pub manifest: Manifest,
    pub opts: ReportOpts,
    source: ModelSource,
    models: BTreeMap<String, Box<dyn Backend>>,
}

impl ReportCtx {
    pub fn new(opts: ReportOpts) -> Result<Self> {
        let manifest = Manifest::load(&opts.artifacts_root)?;
        let source = ModelSource::Artifacts(opts.artifacts_root.clone());
        Ok(Self { manifest, opts, source, models: BTreeMap::new() })
    }

    /// Models selected for this run, in manifest order.
    pub fn model_names(&self) -> Vec<String> {
        if self.opts.models.is_empty() {
            self.manifest.model_names()
        } else {
            self.opts.models.clone()
        }
    }

    /// Load (and cache) a model backend.
    pub fn model(&mut self, name: &str) -> Result<&dyn Backend> {
        if !self.models.contains_key(name) {
            let b = load_backend_with(&self.source, name, &self.opts.threads)
                .with_context(|| format!("loading model {name}"))?;
            self.models.insert(name.to_string(), b);
        }
        Ok(self.models[name].as_ref())
    }

    pub fn results_dir(&self) -> PathBuf {
        self.manifest.root.join("results")
    }

    /// Measure (or load a cached) aggregate trace for one (model, task,
    /// L, gamma) cell: runs the engine over `n_prompts` task prompts and
    /// merges the traces.
    pub fn trace_for(
        &mut self,
        model_name: &str,
        task: &str,
        max_draft: usize,
        gamma: f32,
    ) -> Result<SpecTrace> {
        let dir = self.results_dir();
        if !self.opts.fresh {
            if let Some(rec) = load_trace(&dir, model_name, task, max_draft, gamma) {
                if rec.gen_len == self.opts.gen_len {
                    return Ok(rec.trace);
                }
            }
        }
        let taskset = load_task(&self.manifest, task)?;
        let n = self.opts.n_prompts.min(taskset.prompts.len());
        let gen_len = self.opts.gen_len;
        let model = self.model(model_name)?;
        let engine = Engine::new(model);
        let mut merged = SpecTrace::default();
        for prompt in taskset.prompts.iter().take(n) {
            let cfg = SpecConfig {
                max_draft,
                gamma,
                sampling: SamplingParams::greedy(),
                gen_len,
                ..Default::default()
            };
            let res = engine.generate_spec(prompt, &cfg)?;
            merged.merge(&res.trace);
            merged.prompt_len = res.trace.prompt_len;
        }
        let rec = TraceRecord {
            model: model_name.to_string(),
            task: task.to_string(),
            max_draft,
            gamma,
            gen_len,
            trace: merged.clone(),
        };
        save_trace(&dir, &rec)?;
        Ok(merged)
    }

    /// Persist an experiment's JSON result.
    pub fn save_result(&self, exp: &str, value: &Value) -> Result<()> {
        let dir = self.results_dir();
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{exp}.json"));
        std::fs::write(&path, crate::util::json::write(value))
            .with_context(|| format!("writing {}", path.display()))?;
        println!("  -> saved {}", path.display());
        Ok(())
    }
}
