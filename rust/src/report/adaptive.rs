//! E12: static-vs-adaptive draft-length sweep on the builtin zoo.
//!
//! Runs the single-sequence engine over a ladder of static draft lengths
//! and once with the per-sequence adaptive controller, then compares
//! weight bytes streamed per produced token — the deterministic stand-in
//! for decode cost (tokens and byte counts are bit-exact across runs and
//! machines, unlike wall-clock).  Requires no artifacts: models come from
//! the builtin synthetic zoo, so the experiment doubles as the CI gate
//! for the controller.
//!
//! The gate: the adaptive run must land within [`BYTES_TOLERANCE`] of the
//! best static ladder point, byte-wise.  The controller starts from a
//! neutral prior and pays a few exploratory iterations, so exact parity
//! is not expected; landing *near* the best static point without being
//! told which one it is, is the whole point.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::{load_backend_with, ModelSource, NativeConfig, TrafficSnapshot};
use crate::specdec::{AdaptiveConfig, Engine, SpecConfig};
use crate::util::json::Value;

/// Static draft-length ladder the adaptive run competes against.
pub const STATIC_LADDER: [usize; 4] = [2, 4, 8, 16];

/// Adaptive may stream at most this multiple of the best static arm's
/// bytes per token (cold-start exploration is paid inside this margin).
pub const BYTES_TOLERANCE: f64 = 1.25;

/// Below this generation length the cold-start fraction dominates and the
/// byte gate is skipped (the sweep still prints and emits BENCH_JSON).
const GATE_MIN_GEN_LEN: usize = 128;

/// Builtin-zoo models the sweep runs by default (a subset keeps the CI
/// leg fast; `--models` overrides).
const DEFAULT_MODELS: [&str; 2] = ["vicuna-7b-tiny", "llama3.2-3b-tiny"];

const PROMPT: &[u8] = b"Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ";

/// One measured arm of the sweep.
struct Arm {
    label: String,
    tokens: usize,
    wall_s: f64,
    bytes_per_token: f64,
    accept_rate: f64,
    /// Mean drafted tokens per iteration over the final quarter of
    /// iterations — for adaptive arms, where the controller converged.
    late_draft_len: f64,
}

/// Decode-path weight bytes in a traffic delta (prefill excluded: it is
/// identical across arms and would dilute the comparison).
fn decode_bytes(t: &TrafficSnapshot) -> u64 {
    t.draft_bytes + t.full_bytes + t.verify_bytes
}

fn delta(before: &TrafficSnapshot, after: &TrafficSnapshot) -> u64 {
    decode_bytes(after).saturating_sub(decode_bytes(before))
}

fn run_arm(engine: &Engine, cfg: &SpecConfig, label: &str) -> Result<Arm> {
    let before = engine.backend().traffic();
    let t0 = Instant::now();
    let out = engine.generate_spec(PROMPT, cfg)?;
    let wall_s = t0.elapsed().as_secs_f64();
    let after = engine.backend().traffic();
    let bytes = delta(&before, &after);
    let iters = &out.trace.iterations;
    let tail = &iters[iters.len() - iters.len() / 4..];
    let late_draft_len = if tail.is_empty() {
        0.0
    } else {
        tail.iter().map(|i| i.drafted as f64).sum::<f64>() / tail.len() as f64
    };
    Ok(Arm {
        label: label.to_string(),
        tokens: out.tokens.len(),
        wall_s,
        bytes_per_token: if out.tokens.is_empty() {
            0.0
        } else {
            bytes as f64 / out.tokens.len() as f64
        },
        accept_rate: out.trace.accept_rate(),
        late_draft_len,
    })
}

/// Run the sweep; the returned JSON mirrors the printed table (the
/// artifact-backed report run persists it under `results/`).
pub fn run_adaptive(native: &NativeConfig, gen_len: usize, models: &[String]) -> Result<Value> {
    println!("\n== E12: static vs adaptive draft length (builtin zoo, gen_len {gen_len}) ==");
    let names: Vec<String> = if models.is_empty() {
        DEFAULT_MODELS.iter().map(|s| s.to_string()).collect()
    } else {
        models.to_vec()
    };
    let mut out = BTreeMap::new();
    for name in &names {
        let backend = load_backend_with(&ModelSource::Builtin, name, native)?;
        let engine = Engine::new(backend.as_ref());

        // Warm the traffic meters so the adaptive run's cost ratios are
        // measured, not the compiled-in fallbacks (the counters are never
        // drained here; arms are measured as snapshot deltas).
        engine.generate_spec(
            PROMPT,
            &SpecConfig { max_draft: 4, gen_len: 16, ..Default::default() },
        )?;

        let mut arms = Vec::new();
        for l in STATIC_LADDER {
            let cfg = SpecConfig { max_draft: l, gen_len, ..Default::default() };
            arms.push(run_arm(&engine, &cfg, &format!("static_L{l}"))?);
        }
        // Faster EWMA than the serving default: the sweep is one sequence,
        // so convergence has to happen within a single generation.
        let mut ac = AdaptiveConfig::enabled();
        ac.alpha = 0.2;
        let cfg = SpecConfig { max_draft: 16, adaptive: ac, gen_len, ..Default::default() };
        arms.push(run_arm(&engine, &cfg, "adaptive")?);

        println!("\n  {name}");
        println!(
            "  {:<12} {:>7} {:>9} {:>13} {:>8} {:>10}",
            "arm", "tokens", "tok/s", "bytes/tok", "r", "late L-bar"
        );
        for a in &arms {
            let tps = if a.wall_s > 0.0 { a.tokens as f64 / a.wall_s } else { 0.0 };
            println!(
                "  {:<12} {:>7} {:>9.1} {:>13.0} {:>8.3} {:>10.2}",
                a.label, a.tokens, tps, a.bytes_per_token, a.accept_rate, a.late_draft_len
            );
            println!(
                "BENCH_JSON {{\"group\":\"report_adaptive\",\"model\":\"{name}\",\"arm\":\"{}\",\"tokens\":{},\"wall_s\":{:.4},\"tokens_per_sec\":{:.3},\"bytes_per_token\":{:.1},\"accept_rate\":{:.4},\"late_draft_len\":{:.3}}}",
                a.label, a.tokens, a.wall_s, tps, a.bytes_per_token, a.accept_rate,
                a.late_draft_len
            );
        }

        let best_static = arms[..arms.len() - 1]
            .iter()
            .map(|a| a.bytes_per_token)
            .fold(f64::INFINITY, f64::min);
        let adaptive = arms.last().expect("adaptive arm");
        if gen_len >= GATE_MIN_GEN_LEN {
            anyhow::ensure!(
                adaptive.tokens > 0 && adaptive.bytes_per_token > 0.0,
                "adaptive arm on {name} produced no traffic"
            );
            anyhow::ensure!(
                adaptive.bytes_per_token <= best_static * BYTES_TOLERANCE,
                "adaptive draft control on {name} streamed {:.0} B/tok vs best static {:.0} \
                 (allowed {:.0}); controller failed to track the accept rate",
                adaptive.bytes_per_token,
                best_static,
                best_static * BYTES_TOLERANCE
            );
            println!(
                "  gate OK: adaptive {:.0} B/tok <= best static {:.0} x {BYTES_TOLERANCE}",
                adaptive.bytes_per_token, best_static
            );
        } else {
            println!("  gate skipped (gen_len {gen_len} < {GATE_MIN_GEN_LEN})");
        }

        out.insert(
            name.clone(),
            Value::Obj(
                arms.iter()
                    .map(|a| {
                        (
                            a.label.clone(),
                            Value::Obj(
                                [
                                    ("tokens".to_string(), Value::Num(a.tokens as f64)),
                                    (
                                        "bytes_per_token".to_string(),
                                        Value::Num(a.bytes_per_token),
                                    ),
                                    ("accept_rate".to_string(), Value::Num(a.accept_rate)),
                                    (
                                        "late_draft_len".to_string(),
                                        Value::Num(a.late_draft_len),
                                    ),
                                ]
                                .into_iter()
                                .collect(),
                            ),
                        )
                    })
                    .collect(),
            ),
        );
    }
    println!("\n(adaptive must land near the best static point without being told which)");
    Ok(Value::Obj(out))
}
