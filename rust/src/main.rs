//! `speq` — the SPEQ coordinator binary.
//!
//! Subcommands:
//!   info                         model summary (artifacts or builtin zoo)
//!   report --exp <id|all>        regenerate a paper table/figure (DESIGN.md §5)
//!   generate --model M --prompt  one-off generation (spec + AR comparison)
//!   serve --model M --workers N  run the serving coordinator on a demo workload,
//!                                or with --addr H:P, serve HTTP (SSE streaming,
//!                                /healthz, /metrics) until --duration-s expires
//!   loadgen --addr H:P           drive a running server: closed-loop (--users)
//!                                or open-loop Poisson (--rate), BENCH_JSON out
//!   bench-accel                  quick accelerator sanity sweep
//!
//! Every subcommand except `report` works without artifacts: models fall
//! back to the builtin synthetic zoo on the native backend.
//!
//! Common flags: --artifacts <dir> (default ./artifacts or $SPEQ_ARTIFACTS).

use anyhow::Result;
use speq::accel::{paper_dims, Accel, ArrayMode};
use speq::coordinator::{Mode, Priority, Server, ServerConfig, SubmitParams};
use speq::model::{Manifest, SamplingParams};
use speq::net::{LoadConfig, LoadMode, NetConfig, NetServer, Scenario};
use speq::report::{run_accel_replay, run_adaptive, run_experiment, ReportCtx, ReportOpts, EXPERIMENTS};
use speq::runtime::{
    builtin_config, builtin_model_names, load_backend_with, Backend, ModelSource, NativeConfig,
    SimdLevel,
};
use speq::specdec::{AdaptiveConfig, Engine, SpecConfig};
use speq::util::cli::Args;
use speq::workload::{load_task_or_builtin, task_names};

fn main() {
    let args = Args::from_env();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn artifacts_root(args: &Args) -> std::path::PathBuf {
    args.get("artifacts").map(Into::into).unwrap_or_else(Manifest::default_root)
}

/// An explicit `--artifacts` flag always selects artifacts (so a typo'd
/// path errors instead of silently serving the builtin zoo); otherwise
/// artifacts are used when the default root has a manifest.
fn model_source(args: &Args) -> ModelSource {
    match args.get("artifacts") {
        Some(root) => ModelSource::Artifacts(root.into()),
        None => ModelSource::auto(),
    }
}

/// Native runtime config: `--threads N` (0 = auto-detect) beats the
/// `SPEQ_THREADS` env default, and `--simd
/// <auto|scalar|sse4.1|avx2|neon>` beats `SPEQ_SIMD` (default: best
/// detected tier).  Neither knob ever changes output bits — both are
/// purely wall-clock knobs.
fn native_config(args: &Args) -> NativeConfig {
    let mut native =
        NativeConfig::with_threads(args.get_usize("threads", NativeConfig::default().threads));
    if let Some(s) = args.get("simd") {
        match SimdLevel::parse(s) {
            Some(level) => native.simd = level.resolve(),
            None => eprintln!(
                "warning: unknown --simd {s:?} (auto|scalar|sse4.1|avx2|neon); using {:?}",
                native.simd.name()
            ),
        }
    }
    native
}

/// Arm structured tracing when `--trace-out` was given (`serve` arms
/// unconditionally so `/debug/trace` always has data).
fn arm_trace_out(args: &Args) {
    if args.get("trace-out").is_some() {
        speq::trace::arm();
    }
}

/// After a run: export everything still retained in the rings to the
/// `--trace-out` sink, if one was requested.
fn write_trace_out(args: &Args) -> Result<()> {
    if let Some(path) = args.get("trace-out") {
        speq::trace::write_file(std::path::Path::new(path), usize::MAX)?;
        println!("trace: wrote {path} (load in Perfetto / chrome://tracing)");
    }
    Ok(())
}

fn dispatch(args: &Args) -> Result<()> {
    // Fault-injection plan: `--faults <spec>` beats `SPEQ_FAULTS`.  With
    // neither set, every probe stays a single relaxed atomic load.
    match args.get("faults") {
        Some(spec) => speq::faults::install(speq::faults::FaultPlan::parse(spec)?),
        None => speq::faults::init_from_env()?,
    }
    // Structured tracing: `SPEQ_TRACE=1` arms recording for any
    // subcommand; `--trace-out` / `serve` arm it themselves below.
    speq::trace::init_from_env();
    match args.subcommand.as_deref() {
        Some("info") => info(args),
        Some("report") => report(args),
        Some("generate") => generate(args),
        Some("serve") => serve(args),
        Some("loadgen") => loadgen(args),
        Some("bench-accel") => bench_accel(args),
        Some("version") => {
            println!("speq {}", speq::version());
            Ok(())
        }
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}\n");
            }
            println!(
                "usage: speq <info|report|generate|serve|loadgen|bench-accel|version> [flags]\n\
                 \n\
                 speq report --exp <{}|all> [--models a,b] [--n-prompts N] [--gen-len N] [--fresh] [--threads T]\n\
                 \x20          [--trace-in FILE]   (accel-replay: replay a recorded trace)\n\
                 speq generate --model <name> --prompt <text> [--gen-len N] [--temperature T]\n\
                 \x20          [--adaptive] [--threads T] [--trace-out FILE]\n\
                 speq serve --model <name> [--workers N] [--requests N] [--threads T]\n\
                 speq serve --addr 127.0.0.1:8080 [--model M] [--workers N] [--max-batch B] [--queue Q]\n\
                 \x20          [--deadline-ms D] [--duration-s S] [--threads T] [--trace-out FILE]\n\
                 \x20          [--kv-page-budget P] [--faults SPEC]   (HTTP front end)\n\
                 speq loadgen --addr 127.0.0.1:8080 [--mode closed|open] [--users N] [--rate R]\n\
                 \x20          [--scenario oneshot|multiturn|slowreader|cancelstorm]\n\
                 \x20          [--requests N] [--gen-len N] [--retries R]\n\
                 \x20          [--adaptive] [--deadline-ms D] [--smoke] [--trace-out FILE]\n\
                 speq info\n\
                 \n\
                 --threads T sizes the native kernel worker pool (0 = auto, default\n\
                 $SPEQ_THREADS or 1); output bits are identical for every T.\n\
                 --simd <auto|scalar|sse4.1|avx2|neon> forces the kernel SIMD tier\n\
                 (default $SPEQ_SIMD or best detected); output bits are identical\n\
                 for every tier.\n\
                 --faults SPEC (or $SPEQ_FAULTS) arms the fault-injection plan, e.g.\n\
                 \x20 'seed=7;step.verify@3=error;page.alloc%0.01=exhaust' (see README).\n\
                 --trace-out FILE (or $SPEQ_TRACE=1) arms structured tracing and writes\n\
                 \x20 a Perfetto-loadable Chrome trace JSON; `serve` always records and\n\
                 \x20 also exposes GET /debug/trace?last=N (loadgen --trace-out pulls it).",
                EXPERIMENTS.join("|")
            );
            Ok(())
        }
    }
}

fn info(args: &Args) -> Result<()> {
    if let Some(manifest) = model_source(args).manifest()? {
        println!("artifacts: {} (v{})", manifest.root.display(), manifest.version);
        println!("group size: {} | prompt len: {}", manifest.group_size, manifest.prompt_len);
        println!("\n{:<18} {:>8} {:>7} {:>6} {:>6} {:>9} {:>12}", "model", "params", "layers", "d", "ff", "loss", "paper analog");
        for name in manifest.model_names() {
            let e = manifest.model(&name)?;
            println!(
                "{name:<18} {:>8} {:>7} {:>6} {:>6} {:>9.3} {:>12}",
                e.config.param_count,
                e.config.n_layers,
                e.config.d_model,
                e.config.d_ff,
                e.train.loss_last,
                e.config.paper_analog
            );
        }
        println!("\ntasks: {:?}", manifest.tasks.keys().collect::<Vec<_>>());
    } else {
        println!(
            "no artifacts at {} — builtin synthetic zoo (native backend):",
            artifacts_root(args).display()
        );
        println!("\n{:<18} {:>8} {:>7} {:>6} {:>6} {:>12}", "model", "params", "layers", "d", "ff", "paper analog");
        for name in builtin_model_names() {
            let c = builtin_config(name)?;
            println!(
                "{name:<18} {:>8} {:>7} {:>6} {:>6} {:>12}",
                c.param_count, c.n_layers, c.d_model, c.d_ff, c.paper_analog
            );
        }
        println!("\ntasks: {:?} (builtin prompts)", task_names());
    }
    Ok(())
}

fn report(args: &Args) -> Result<()> {
    let exp = args.get_or("exp", "all").to_string();
    let opts = ReportOpts {
        artifacts_root: artifacts_root(args),
        models: args
            .get("models")
            .map(|m| m.split(',').map(str::to_string).collect())
            .unwrap_or_default(),
        n_prompts: args.get_usize("n-prompts", 4),
        gen_len: args.get_usize("gen-len", 256),
        ppl_windows: args.get_usize("ppl-windows", 12),
        fresh: args.has("fresh"),
        threads: native_config(args),
        trace_in: args.get("trace-in").map(Into::into),
    };
    // `adaptive` and `accel-replay` are defined on the builtin zoo: when
    // no artifacts exist, run them standalone so CI can gate them without
    // a trained checkpoint (with artifacts they go through the ctx for
    // results/).
    if Manifest::load(&opts.artifacts_root).is_err() {
        match exp.as_str() {
            "adaptive" => {
                run_adaptive(&opts.threads, opts.gen_len, &opts.models)?;
                return Ok(());
            }
            "accel-replay" => {
                run_accel_replay(
                    &opts.threads,
                    opts.gen_len,
                    &opts.models,
                    opts.trace_in.as_deref(),
                )?;
                return Ok(());
            }
            _ => {}
        }
    }
    let mut ctx = ReportCtx::new(opts)?;
    run_experiment(&mut ctx, &exp)
}

fn generate(args: &Args) -> Result<()> {
    arm_trace_out(args);
    let model_name = args.get_or("model", "vicuna-7b-tiny");
    let prompt = args
        .get("prompt")
        .unwrap_or("Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ")
        .as_bytes()
        .to_vec();
    let gen_len = args.get_usize("gen-len", 128);
    let temperature = args.get_f64("temperature", 0.0) as f32;

    let source = model_source(args);
    let native = native_config(args);
    let backend = load_backend_with(&source, model_name, &native)?;
    println!(
        "model {model_name} on {} backend, {} thread(s), simd {} (source: {})",
        backend.backend_name(),
        native.resolved_threads(),
        native.simd.resolve().name(),
        match &source {
            ModelSource::Builtin => "builtin zoo".to_string(),
            ModelSource::Artifacts(p) => p.display().to_string(),
        }
    );
    let engine = Engine::new(backend.as_ref());
    let sampling = SamplingParams { temperature, seed: args.get_usize("seed", 0) as u64 };

    let cfg = SpecConfig {
        max_draft: args.get_usize("max-draft", 16),
        gamma: args.get_f64("gamma", 0.6) as f32,
        sampling,
        gen_len,
        adaptive: if args.has("adaptive") {
            AdaptiveConfig::enabled()
        } else {
            AdaptiveConfig::default()
        },
    };
    let spec = engine.generate_spec(&prompt, &cfg)?;
    println!("--- speculative ({:?}) ---", spec.wall);
    println!("{}", String::from_utf8_lossy(&spec.tokens));
    println!(
        "\niters {} | draft steps {} | r {:.3} | L-bar {:.2} | accept-len {:.2} | early-exit {:.0}%",
        spec.trace.verify_passes(),
        spec.trace.draft_steps(),
        spec.trace.accept_rate(),
        spec.trace.mean_draft_len(),
        spec.trace.mean_accept_len(),
        spec.trace.early_exit_rate() * 100.0
    );
    if temperature == 0.0 {
        let ar = engine.generate_ar(&prompt, gen_len, sampling)?;
        println!("\nlossless check vs autoregressive: {}", if ar.tokens == spec.tokens { "IDENTICAL" } else { "MISMATCH!" });
        // Weight-traffic accounting over both runs: the quarter-to-all
        // ratio as a measured number (zeros on backends without counters).
        let t = engine.backend().traffic();
        if !t.is_empty() {
            println!(
                "weight traffic: draft {:.1} KB/tok | full {:.1} KB/tok | verify {:.1} KB/row | quarter ratio {:.3}",
                t.draft_bytes_per_token() / 1024.0,
                t.full_bytes_per_token() / 1024.0,
                t.verify_bytes_per_row() / 1024.0,
                t.draft_full_ratio()
            );
        }
        // Simulated accelerator speedup for this very trace at paper scale.
        if let Some(dims) = paper_dims(model_name) {
            let tc = Accel::default().run_trace(dims, &spec.trace, 1024);
            println!(
                "simulated SPEQ accelerator ({}) speedup vs FP16: {:.2}x",
                dims.name,
                tc.speedup()
            );
        }
    }
    write_trace_out(args)
}

fn serve(args: &Args) -> Result<()> {
    // Serving always records: the rings are bounded, the disarmed check
    // is the only alternative cost, and `/debug/trace` (HTTP mode) or
    // `--trace-out` should never come back empty.
    speq::trace::arm();
    let source = model_source(args);
    let cfg = ServerConfig {
        source: source.clone(),
        model: args.get_or("model", "vicuna-7b-tiny").to_string(),
        workers: args.get_usize("workers", 2),
        queue_capacity: args.get_usize("queue", 64),
        max_batch: args.get_usize("max-batch", 8),
        threads: native_config(args),
        kv_page_budget: {
            let b = args.get_usize("kv-page-budget", 0);
            if b > 0 { Some(b as u64) } else { None }
        },
        ..ServerConfig::default()
    };
    if let Some(addr) = args.get("addr") {
        return serve_http(args, addr, cfg);
    }
    let n_requests = args.get_usize("requests", 12);
    let gen_len = args.get_usize("gen-len", 64);
    println!(
        "starting {} schedulers (max_batch {}, {} kernel thread(s) each) on {} ...",
        cfg.workers,
        cfg.max_batch,
        cfg.threads.resolved_threads(),
        cfg.model
    );
    let manifest = source.manifest()?;
    let server = Server::start(cfg)?;

    // Demo workload: cycle through the three task families (each loaded once).
    let tasks: Vec<_> = task_names()
        .iter()
        .map(|&t| load_task_or_builtin(manifest.as_ref(), t, 64, n_requests.max(1)))
        .collect::<Result<_>>()?;
    let mut streams = Vec::new();
    let t0 = std::time::Instant::now();
    for i in 0..n_requests {
        let ts = &tasks[i % 3];
        let prompt = &ts.prompts[i % ts.prompts.len()];
        let (id, stream) = server.submit(
            prompt,
            SubmitParams {
                gen_len,
                mode: Mode::Speculative,
                priority: if i % 4 == 0 { Priority::Interactive } else { Priority::Batch },
                sampling: SamplingParams::greedy(),
                ..Default::default()
            },
        )?;
        streams.push((id, stream));
    }
    for (id, stream) in streams {
        let body = stream.wait()?;
        println!(
            "req {:>3} worker {} | {:>3} tok | {:>7.1} ms | r {:.3}",
            id,
            body.worker,
            body.tokens.len(),
            body.latency_s * 1e3,
            body.trace.accept_rate()
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics().snapshot();
    println!(
        "\n{} requests | {} tokens | {:.1} tok/s | p50 {:.0} ms | p95 {:.0} ms | p99 {:.0} ms",
        snap.completed,
        snap.tokens,
        snap.tokens as f64 / wall,
        snap.latency_p50_ms,
        snap.latency_p95_ms,
        snap.latency_p99_ms
    );
    println!(
        "batch occupancy: mean {:.2} seqs/step | failed {}",
        snap.batch_occupancy_mean, snap.failed
    );
    println!(
        "phase means: queue {:.1} ms | prefill {:.1} ms | draft {:.1} ms | verify {:.1} ms | stall {:.1} ms",
        snap.phase_queue_wait_mean_ms,
        snap.phase_prefill_mean_ms,
        snap.phase_draft_mean_ms,
        snap.phase_verify_mean_ms,
        snap.phase_stall_mean_ms
    );
    if !snap.traffic.is_empty() {
        println!(
            "weight traffic: draft {:.1} KB/tok | full {:.1} KB/tok | verify {:.1} KB/row | quarter ratio {:.3}",
            snap.bytes_per_token_draft / 1024.0,
            snap.bytes_per_token_full / 1024.0,
            snap.traffic.verify_bytes_per_row() / 1024.0,
            snap.draft_traffic_ratio
        );
    }
    server.shutdown();
    write_trace_out(args)
}

/// `speq serve --addr H:P`: the HTTP/SSE front end.  Runs until
/// `--duration-s` expires (0 = forever), then drains gracefully.
fn serve_http(args: &Args, addr: &str, cfg: ServerConfig) -> Result<()> {
    let duration_s = args.get_usize("duration-s", 0);
    let deadline_ms = args.get_usize("deadline-ms", 0);
    let net_cfg = NetConfig {
        addr: addr.to_string(),
        server: cfg,
        default_deadline: if deadline_ms > 0 {
            Some(std::time::Duration::from_millis(deadline_ms as u64))
        } else {
            None
        },
        ..NetConfig::default()
    };
    let workers = net_cfg.server.workers;
    let max_batch = net_cfg.server.max_batch;
    let threads = net_cfg.server.threads.resolved_threads();
    let model = net_cfg.server.model.clone();
    let mut server = NetServer::bind(net_cfg)?;
    println!(
        "speq serving {model} on http://{} ({} schedulers, max_batch {}, {} kernel thread(s))",
        server.addr(),
        workers,
        max_batch,
        threads
    );
    println!(
        "routes: POST /v1/generate | POST /v1/stream (SSE) | GET /healthz | GET /metrics | GET /debug/trace"
    );
    let t0 = std::time::Instant::now();
    loop {
        std::thread::sleep(std::time::Duration::from_millis(200));
        if duration_s > 0 && t0.elapsed().as_secs() >= duration_s as u64 {
            break;
        }
    }
    println!("duration elapsed; draining ...");
    let drained = server.shutdown(std::time::Duration::from_secs(30));
    let snap = server.snapshot();
    println!(
        "served {} requests ({} tokens, {} rejected, {} cancelled, {} failed), drained: {}",
        snap.completed, snap.tokens, snap.rejected, snap.cancelled, snap.failed, drained
    );
    write_trace_out(args)
}

/// `speq loadgen`: drive a running server over real sockets and report
/// throughput, goodput, and latency percentiles (+ one BENCH_JSON line).
fn loadgen(args: &Args) -> Result<()> {
    let smoke = args.has("smoke");
    let mode = match args.get_or("mode", "closed") {
        "closed" => LoadMode::Closed { users: args.get_usize("users", 4) },
        "open" => LoadMode::Open { rate_rps: args.get_f64("rate", 8.0) },
        other => anyhow::bail!("unknown loadgen mode {other:?} (closed|open)"),
    };
    let scenario = match Scenario::from_name(args.get_or("scenario", "oneshot")) {
        Some(s) => s,
        None => anyhow::bail!(
            "unknown loadgen scenario {:?} (oneshot|multiturn|slowreader|cancelstorm)",
            args.get_or("scenario", "oneshot")
        ),
    };
    // --smoke only shrinks the default request count and turns on the CI
    // assertions below; an explicit --mode/--users/--rate is honored.
    let cfg = LoadConfig {
        addr: args.get_or("addr", "127.0.0.1:8080").to_string(),
        mode,
        requests: args.get_usize("requests", if smoke { 8 } else { 32 }),
        gen_len: args.get_usize("gen-len", 32),
        seed: args.get_usize("seed", 0) as u64,
        scenario,
        adaptive: args.has("adaptive"),
        deadline_ms: {
            let d = args.get_usize("deadline-ms", 0);
            if d > 0 { Some(d as u64) } else { None }
        },
        timeout: std::time::Duration::from_secs(args.get_usize("timeout-s", 60) as u64),
        retries: args.get_usize("retries", 2),
    };
    let report = speq::net::loadgen::run(&cfg)?;
    report.print();
    println!("{}", report.bench_json());
    // The engine trace lives server-side: pull it over HTTP before the
    // smoke gates so a failed gate still leaves the trace for triage.
    if let Some(path) = args.get("trace-out") {
        let body = speq::net::loadgen::fetch_trace(&cfg.addr, 1_000_000, cfg.timeout)?;
        std::fs::write(path, &body)?;
        println!("trace: wrote {path} ({} bytes from {})", body.len(), cfg.addr);
    }
    if smoke {
        if scenario == Scenario::Cancelstorm {
            // Storm clients hang up on purpose, so "all complete" is the
            // wrong gate: require that the patient readers all finished,
            // the storm actually cancelled work, and nothing *failed*.
            anyhow::ensure!(
                report.completed > 0 && report.cancelled > 0,
                "cancelstorm smoke: {} completed, {} cancelled (need both nonzero)",
                report.completed,
                report.cancelled
            );
            anyhow::ensure!(
                report.failed == 0,
                "cancelstorm smoke: {} requests failed (disconnects must cancel, not error)",
                report.failed
            );
        } else {
            // CI gate: every request must complete and produce tokens.
            anyhow::ensure!(
                report.completed == report.requests && report.failed == 0,
                "loadgen smoke failed: {}/{} completed, {} failed",
                report.completed,
                report.requests,
                report.failed
            );
        }
        anyhow::ensure!(report.goodput_rps > 0.0, "loadgen smoke: zero goodput");
        anyhow::ensure!(report.tokens > 0, "loadgen smoke: zero tokens streamed");
        if scenario == Scenario::Multiturn {
            // The shared system prompt must actually hit the prefix cache:
            // pull /metrics and require a nonzero hit-token counter.
            let page = speq::net::loadgen::fetch_metrics(&cfg.addr, cfg.timeout)?;
            let hits = speq::net::loadgen::metric_value(
                &page,
                "speq_prefix_cache_hit_tokens_total",
            )
            .unwrap_or(0.0);
            anyhow::ensure!(
                hits > 0.0,
                "loadgen smoke: multiturn scenario produced no prefix-cache hits"
            );
            println!("prefix cache hit tokens: {hits}");
        }
        println!("loadgen smoke OK");
    }
    Ok(())
}

fn bench_accel(_args: &Args) -> Result<()> {
    let accel = Accel::default();
    println!("accelerator sanity sweep (paper dims, ctx 1024):");
    for dims in speq::accel::PAPER_MODELS.iter() {
        let full = accel.decode_step_cost(dims, 1024, ArrayMode::Full);
        let quant = accel.decode_step_cost(dims, 1024, ArrayMode::Quant);
        let ver = accel.verify_cost(dims, 1024, 17);
        println!(
            "{:<14} AR {:>9} cyc ({:>6.2} ms) | draft {:>9} cyc ({:.2}x cheaper) | verify17 {:>9} cyc ({:.2}x AR)",
            dims.name,
            full.cycles,
            full.time_s(&accel.cfg) * 1e3,
            quant.cycles,
            full.cycles as f64 / quant.cycles as f64,
            ver.cycles,
            ver.cycles as f64 / full.cycles as f64,
        );
    }
    Ok(())
}
