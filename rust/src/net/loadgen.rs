//! Load generator: a std-only HTTP/SSE client plus closed-loop and
//! open-loop drivers against a running [`NetServer`], reporting
//! tokens/sec, goodput, and TTFT / total-latency percentiles.
//!
//! * **Closed loop** (`N` concurrent users): each user issues its next
//!   request as soon as the previous one finishes — throughput-oriented,
//!   models a fixed worker pool.
//! * **Open loop** (fixed arrival rate): request arrivals follow a
//!   Poisson process (exponential inter-arrivals from the deterministic
//!   [`util::rng`]), independent of completions — latency-oriented,
//!   models internet traffic and exposes queueing delay that closed-loop
//!   measurement hides.
//!
//! The client drives `POST /v1/stream` so it observes true TTFT (first
//! SSE `chunk` event) over a real socket; byte tokens are recovered from
//! each event's `tokens` array, so the streamed output can be compared
//! bit-for-bit against offline generation.
//!
//! [`NetServer`]: super::NetServer
//! [`util::rng`]: crate::util::rng

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::api::GenerateRequest;
use crate::util::json;
use crate::util::rng::Rng;

/// Fixed prompt set cycled by request index (all ASCII, valid for every
/// zoo model's byte vocabulary).
pub const PROMPTS: &[&str] = &[
    "Q: ada has 3 apples and finds 4 more. how many apples now?\nA: ",
    "def add_two(x):\n    return ",
    "USER: hello, can we talk about music?\nBOT: ",
    "Q: bob has 9 coins and spends 2. how many coins left?\nA: ",
];

/// Common system prompt opening every multiturn conversation (75 bytes =
/// four full 16-token KV pages of shared prefix, one token per byte).
pub const SYSTEM_PROMPT: &str =
    "SYSTEM: you are a concise assistant. answer briefly and helpfully, please.\n";

/// Scenario shaping the prompt stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// Independent one-shot requests cycling through [`PROMPTS`].
    Oneshot,
    /// Multi-turn conversations that all open with [`SYSTEM_PROMPT`]:
    /// heavy-tailed turns per session (Pareto-shaped, clamped to 1..=8),
    /// every turn carrying its conversation's session id.  Exercises the
    /// backend prefix cache (the system prompt is shared across sessions)
    /// and session history (turns within a session are serialized).
    Multiturn,
    /// One-shot prompts drained through a deliberately slow SSE reader
    /// (a per-read delay trickles the chunked body): exercises the
    /// server's bounded-write path and shows whether one congested client
    /// can stall co-batched streams.
    Slowreader,
    /// One-shot prompts where bursts of clients hang up mid-stream after
    /// a few tokens (every fourth request reads to completion, so goodput
    /// stays nonzero): exercises disconnect-driven cancellation, KV slot
    /// reclamation, and the admit/cancel race under churn.
    Cancelstorm,
}

impl Scenario {
    pub fn as_str(&self) -> &'static str {
        match self {
            Scenario::Oneshot => "oneshot",
            Scenario::Multiturn => "multiturn",
            Scenario::Slowreader => "slowreader",
            Scenario::Cancelstorm => "cancelstorm",
        }
    }

    /// Parse a CLI scenario name.
    pub fn from_name(name: &str) -> Option<Self> {
        Some(match name {
            "oneshot" => Scenario::Oneshot,
            "multiturn" => Scenario::Multiturn,
            "slowreader" => Scenario::Slowreader,
            "cancelstorm" => Scenario::Cancelstorm,
            _ => return None,
        })
    }
}

/// Client-side read shaping for one streamed request.
#[derive(Debug, Clone, Copy, Default)]
pub struct StreamOptions {
    /// Sleep this long before every chunk read (slow-reader emulation).
    pub read_delay: Option<Duration>,
    /// Hang up (hard socket close, no terminal event consumed) once this
    /// many tokens have been streamed — a mid-stream client disconnect.
    pub hangup_after_tokens: Option<usize>,
}

/// Deterministic multiturn schedule: map global request index `i` to its
/// `(session id, turn index)`.  Session turn counts are drawn once from a
/// seeded Pareto-shaped distribution — most conversations are 1–2 turns,
/// a few run to the 8-turn clamp — so the schedule is identical for every
/// caller with the same seed (threads need no shared state).
pub fn multiturn_slot(i: usize, seed: u64) -> (u64, usize) {
    let mut rng = Rng::seed_from_u64(seed ^ 0x4d75_6c74); // "Mult"
    let mut covered = 0usize;
    let mut session = 0u64;
    loop {
        let u = rng.gen_f64();
        let turns = (((1.0 - u).powf(-0.8)).ceil() as usize).clamp(1, 8);
        if i < covered + turns {
            return (0x4d55_0000 + session, i - covered);
        }
        covered += turns;
        session += 1;
    }
}

/// How a streamed request terminated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Terminal {
    /// `done` event received.
    Done,
    /// `cancelled` event (deadline or disconnect).
    Cancelled,
    /// `error` event or a non-200 response other than 429.
    Error,
    /// 429 (admission control) — counted separately from errors.
    Rejected,
    /// Connection ended without a terminal event.
    Dropped,
}

/// One request's observation.
#[derive(Debug, Clone)]
pub struct StreamOutcome {
    pub status: u16,
    pub terminal: Terminal,
    /// Byte tokens recovered from the SSE `chunk` events, in order.
    pub tokens: Vec<u8>,
    /// Seconds to the first `chunk` event.
    pub ttft_s: Option<f64>,
    pub total_s: f64,
    /// Raw `data:` payload of the terminal `done` event, if any.
    pub done_data: Option<String>,
    /// Response body of a non-200 answer (error JSON), if any.
    pub error_body: Option<String>,
    /// `Retry-After` seconds, when the server answered 429.
    pub retry_after_s: Option<u64>,
}

/// Issue one `POST /v1/stream` request and consume the SSE stream.
pub fn stream_once(
    addr: &str,
    greq: &GenerateRequest,
    timeout: Duration,
) -> Result<StreamOutcome> {
    stream_once_opts(addr, greq, timeout, StreamOptions::default())
}

/// [`stream_once`] with client-side read shaping (slow reads, mid-stream
/// hangups) for the failure-mode scenarios.
pub fn stream_once_opts(
    addr: &str,
    greq: &GenerateRequest,
    timeout: Duration,
    opts: StreamOptions,
) -> Result<StreamOutcome> {
    let t0 = Instant::now();
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(timeout)).ok();
    let body = greq.to_json();
    let mut w = stream.try_clone().context("clone socket")?;
    write!(
        w,
        "POST /v1/stream HTTP/1.1\r\nhost: {addr}\r\ncontent-type: application/json\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
        body.len()
    )?;
    w.write_all(body.as_bytes())?;
    w.flush()?;

    let mut r = BufReader::new(stream);
    let mut line = String::new();
    r.read_line(&mut line).context("read status line")?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line {line:?}"))?;

    let mut chunked = false;
    let mut content_length = 0usize;
    let mut retry_after_s = None;
    loop {
        let mut l = String::new();
        if r.read_line(&mut l)? == 0 {
            anyhow::bail!("connection closed in response headers");
        }
        let l = l.trim_end().to_ascii_lowercase();
        if l.is_empty() {
            break;
        }
        if let Some(v) = l.strip_prefix("transfer-encoding:") {
            chunked = v.trim() == "chunked";
        }
        if let Some(v) = l.strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
        if let Some(v) = l.strip_prefix("retry-after:") {
            retry_after_s = v.trim().parse().ok();
        }
    }

    if status != 200 || !chunked {
        let mut buf = vec![0u8; content_length];
        r.read_exact(&mut buf).context("read error body")?;
        let terminal = if status == 429 { Terminal::Rejected } else { Terminal::Error };
        return Ok(StreamOutcome {
            status,
            terminal,
            tokens: Vec::new(),
            ttft_s: None,
            total_s: t0.elapsed().as_secs_f64(),
            done_data: None,
            error_body: Some(String::from_utf8_lossy(&buf).into_owned()),
            retry_after_s,
        });
    }

    // ---- chunked SSE body ----
    let mut payload: Vec<u8> = Vec::new();
    let mut scan = 0usize;
    let mut tokens: Vec<u8> = Vec::new();
    let mut ttft_s: Option<f64> = None;
    let mut terminal = Terminal::Dropped;
    let mut done_data: Option<String> = None;
    'read: loop {
        if let Some(d) = opts.read_delay {
            // Slow reader: trickle-drain the stream so server-side chunk
            // writes see a congested socket.
            std::thread::sleep(d);
        }
        let mut szl = String::new();
        if r.read_line(&mut szl)? == 0 {
            break; // EOF without the zero chunk
        }
        let size = usize::from_str_radix(szl.trim(), 16)
            .with_context(|| format!("bad chunk size {szl:?}"))?;
        if size == 0 {
            break; // terminator (trailing CRLF left unread; socket closes)
        }
        let mut chunk = vec![0u8; size + 2]; // payload + CRLF
        r.read_exact(&mut chunk).context("read chunk")?;
        chunk.truncate(size);
        payload.extend_from_slice(&chunk);

        // Parse complete SSE events (blocks separated by a blank line).
        while let Some(rel) = find_sep(&payload[scan..]) {
            let block = payload[scan..scan + rel].to_vec();
            scan += rel + 2;
            let (event, data) = parse_event(&block);
            match event.as_str() {
                "chunk" => {
                    if ttft_s.is_none() {
                        ttft_s = Some(t0.elapsed().as_secs_f64());
                    }
                    if let Ok(v) = json::parse(&data) {
                        if let Some(arr) = v.get("tokens").and_then(json::Value::as_arr) {
                            tokens.extend(arr.iter().filter_map(|n| n.as_usize()).map(|n| n as u8));
                        }
                    }
                    if let Some(k) = opts.hangup_after_tokens {
                        if tokens.len() >= k {
                            // Mid-stream disconnect: hard-close without
                            // consuming a terminal event.  The server must
                            // notice and cancel the sequence.
                            let _ = r.get_ref().shutdown(std::net::Shutdown::Both);
                            return Ok(StreamOutcome {
                                status,
                                terminal: Terminal::Cancelled,
                                tokens,
                                ttft_s,
                                total_s: t0.elapsed().as_secs_f64(),
                                done_data: None,
                                error_body: None,
                                retry_after_s,
                            });
                        }
                    }
                }
                "done" => {
                    terminal = Terminal::Done;
                    done_data = Some(data);
                    continue 'read; // server sends the zero chunk next
                }
                "cancelled" => {
                    terminal = Terminal::Cancelled;
                }
                "error" => {
                    terminal = Terminal::Error;
                }
                _ => {}
            }
        }
    }
    Ok(StreamOutcome {
        status,
        terminal,
        tokens,
        ttft_s,
        total_s: t0.elapsed().as_secs_f64(),
        done_data,
        error_body: None,
        retry_after_s,
    })
}

/// Byte offset of the first SSE event separator (`\n\n`).
fn find_sep(buf: &[u8]) -> Option<usize> {
    buf.windows(2).position(|w| w == b"\n\n")
}

/// Split one SSE block into its `event:` name and `data:` payload.
fn parse_event(block: &[u8]) -> (String, String) {
    let text = String::from_utf8_lossy(block);
    let mut event = String::new();
    let mut data = String::new();
    for line in text.lines() {
        if let Some(v) = line.strip_prefix("event:") {
            event = v.trim().to_string();
        } else if let Some(v) = line.strip_prefix("data:") {
            data = v.trim().to_string();
        }
    }
    (event, data)
}

/// Arrival pattern for a load run.
#[derive(Debug, Clone, Copy)]
pub enum LoadMode {
    /// `users` concurrent clients, each issuing back-to-back requests.
    Closed { users: usize },
    /// Poisson arrivals at `rate_rps` requests/second (open loop).
    Open { rate_rps: f64 },
}

/// Load-run configuration.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    /// Server address, e.g. `127.0.0.1:8080`.
    pub addr: String,
    pub mode: LoadMode,
    /// Total requests to issue.
    pub requests: usize,
    pub gen_len: usize,
    /// Sampling seed sent with every request (generation stays greedy and
    /// deterministic; prompts cycle through [`PROMPTS`]).
    pub seed: u64,
    /// Prompt-stream shape (one-shot prompts or multiturn conversations).
    pub scenario: Scenario,
    /// Request the per-sequence adaptive draft-length controller.
    pub adaptive: bool,
    pub deadline_ms: Option<u64>,
    /// Per-request socket read timeout.
    pub timeout: Duration,
    /// Client-side retries after a 429 rejection or a transport drop,
    /// with seeded jittered exponential backoff (0 disables).  Retries
    /// honor the server's `Retry-After` when it answered 429.
    pub retries: usize,
}

impl Default for LoadConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:8080".to_string(),
            mode: LoadMode::Closed { users: 4 },
            requests: 16,
            gen_len: 32,
            seed: 0,
            scenario: Scenario::Oneshot,
            adaptive: false,
            deadline_ms: None,
            timeout: Duration::from_secs(60),
            retries: 2,
        }
    }
}

/// Latency percentiles, milliseconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct Percentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

fn percentiles_ms(samples: &mut [f64]) -> Percentiles {
    // Shared nearest-rank percentile (util::bench::percentile), s → ms.
    let mut pick = |p: f64| crate::util::bench::percentile(samples, p) * 1e3;
    Percentiles { p50: pick(0.50), p95: pick(0.95), p99: pick(0.99) }
}

/// Aggregated results of one load run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub mode: String,
    pub scenario: String,
    /// Whether requests asked for the adaptive draft-length controller.
    pub adaptive: bool,
    pub requests: usize,
    pub completed: usize,
    pub rejected: usize,
    pub cancelled: usize,
    pub failed: usize,
    /// Retry attempts issued after 429s/drops (0 when retries disabled).
    pub retries: usize,
    pub tokens: u64,
    pub wall_s: f64,
    /// Tokens from *completed* requests per wall-clock second.
    pub tokens_per_s: f64,
    /// Completed requests per wall-clock second.
    pub goodput_rps: f64,
    pub ttft_ms: Percentiles,
    pub total_ms: Percentiles,
}

impl LoadReport {
    /// Human-readable summary (the CLI prints this).
    pub fn print(&self) {
        println!(
            "loadgen [{} {}]: {} requests in {:.2} s | {} ok, {} rejected (429), {} cancelled, {} failed, {} retries",
            self.mode, self.scenario, self.requests, self.wall_s, self.completed,
            self.rejected, self.cancelled, self.failed, self.retries
        );
        println!(
            "  throughput: {:.1} tok/s | goodput {:.2} req/s | {} tokens total",
            self.tokens_per_s, self.goodput_rps, self.tokens
        );
        println!(
            "  TTFT  p50 {:>8.1} ms | p95 {:>8.1} ms | p99 {:>8.1} ms",
            self.ttft_ms.p50, self.ttft_ms.p95, self.ttft_ms.p99
        );
        println!(
            "  total p50 {:>8.1} ms | p95 {:>8.1} ms | p99 {:>8.1} ms",
            self.total_ms.p50, self.total_ms.p95, self.total_ms.p99
        );
    }

    /// One machine-readable `BENCH_JSON` line (same convention as
    /// [`util::bench::Bench::metrics_json`]; CI collects these into
    /// `BENCH_server_*.json` artifacts).
    ///
    /// [`util::bench::Bench::metrics_json`]: crate::util::bench::Bench::metrics_json
    pub fn bench_json(&self) -> String {
        let f = |v: f64| if v.is_finite() { v } else { 0.0 };
        format!(
            "BENCH_JSON {{\"group\":\"net_loadgen\",\"mode\":\"{}\",\"scenario\":\"{}\",\"adaptive\":{},\"requests\":{},\"completed\":{},\"rejected\":{},\"cancelled\":{},\"failed\":{},\"retries\":{},\"tokens\":{},\"wall_s\":{:.4},\"tokens_per_sec\":{:.3},\"goodput_rps\":{:.3},\"ttft_p50_ms\":{:.3},\"ttft_p95_ms\":{:.3},\"ttft_p99_ms\":{:.3},\"total_p50_ms\":{:.3},\"total_p95_ms\":{:.3},\"total_p99_ms\":{:.3}}}",
            self.mode, self.scenario, self.adaptive, self.requests, self.completed, self.rejected,
            self.cancelled, self.failed, self.retries, self.tokens, f(self.wall_s), f(self.tokens_per_s),
            f(self.goodput_rps), f(self.ttft_ms.p50), f(self.ttft_ms.p95),
            f(self.ttft_ms.p99), f(self.total_ms.p50), f(self.total_ms.p95),
            f(self.total_ms.p99),
        )
    }
}

/// The request issued for global request index `i`.
pub fn request_for(i: usize, cfg: &LoadConfig) -> GenerateRequest {
    match cfg.scenario {
        // The failure-mode scenarios reuse the one-shot prompt stream;
        // their character comes from client-side read shaping (see
        // [`stream_options_for`]), not the prompts.
        Scenario::Oneshot | Scenario::Slowreader | Scenario::Cancelstorm => GenerateRequest {
            prompt: PROMPTS[i % PROMPTS.len()].as_bytes().to_vec(),
            gen_len: cfg.gen_len,
            seed: cfg.seed,
            adaptive: cfg.adaptive,
            deadline_ms: cfg.deadline_ms,
            ..GenerateRequest::default()
        },
        Scenario::Multiturn => {
            let (sid, turn) = multiturn_slot(i, cfg.seed);
            // Turn 0 opens the conversation: shared system prompt plus a
            // per-session question (sessions share the prefix, not the
            // whole prompt).  Later turns send only the follow-up; the
            // server prepends the stored session history.
            let prompt = if turn == 0 {
                format!("{SYSTEM_PROMPT}USER: question {}: what should i read today?\nBOT: ", sid & 0xffff)
            } else {
                format!("\nUSER: tell me more about pick {turn}.\nBOT: ")
            };
            GenerateRequest {
                prompt: prompt.into_bytes(),
                gen_len: cfg.gen_len,
                seed: cfg.seed,
                adaptive: cfg.adaptive,
                session: Some(sid),
                deadline_ms: cfg.deadline_ms,
                ..GenerateRequest::default()
            }
        }
    }
}

/// Run the configured load against a live server.
pub fn run(cfg: &LoadConfig) -> Result<LoadReport> {
    let samples: Arc<Mutex<Vec<StreamOutcome>>> = Arc::new(Mutex::new(Vec::new()));
    let retries_total = Arc::new(AtomicUsize::new(0));
    let cfg = Arc::new(cfg.clone());
    let t0 = Instant::now();

    match cfg.mode {
        LoadMode::Closed { users } => {
            let next = Arc::new(AtomicUsize::new(0));
            let mut handles = Vec::new();
            for _ in 0..users.max(1) {
                let cfg = cfg.clone();
                let samples = samples.clone();
                let retries_total = retries_total.clone();
                let next = next.clone();
                handles.push(std::thread::spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= cfg.requests {
                        return;
                    }
                    let (outcome, retries) = issue(i, &cfg);
                    retries_total.fetch_add(retries, Ordering::Relaxed);
                    samples.lock().unwrap().push(outcome);
                }));
            }
            for h in handles {
                let _ = h.join();
            }
        }
        LoadMode::Open { rate_rps } => {
            anyhow::ensure!(rate_rps > 0.0, "open-loop rate must be positive");
            // Poisson arrivals: exponential inter-arrival times from the
            // deterministic RNG, precomputed so dispatch jitter does not
            // perturb the schedule.
            let mut rng = Rng::seed_from_u64(cfg.seed ^ 0x4c6f_6164); // "Load"
            let mut offsets = Vec::with_capacity(cfg.requests);
            let mut t = 0.0f64;
            for _ in 0..cfg.requests {
                let u = rng.gen_f64();
                t += -(1.0 - u).ln() / rate_rps;
                offsets.push(t);
            }
            let start = Instant::now();
            let mut handles = Vec::new();
            for (i, off) in offsets.into_iter().enumerate() {
                let target = start + Duration::from_secs_f64(off);
                let now = Instant::now();
                if target > now {
                    std::thread::sleep(target - now);
                }
                let cfg = cfg.clone();
                let samples = samples.clone();
                let retries_total = retries_total.clone();
                handles.push(std::thread::spawn(move || {
                    let (outcome, retries) = issue(i, &cfg);
                    retries_total.fetch_add(retries, Ordering::Relaxed);
                    samples.lock().unwrap().push(outcome);
                }));
            }
            for h in handles {
                let _ = h.join();
            }
        }
    }

    let wall_s = t0.elapsed().as_secs_f64();
    let samples = Arc::try_unwrap(samples)
        .map_err(|_| anyhow::anyhow!("sample sink still shared"))?
        .into_inner()
        .unwrap();

    let mut completed = 0;
    let mut rejected = 0;
    let mut cancelled = 0;
    let mut failed = 0;
    let mut tokens = 0u64;
    let mut ttfts = Vec::new();
    let mut totals = Vec::new();
    for s in &samples {
        match s.terminal {
            Terminal::Done => {
                completed += 1;
                tokens += s.tokens.len() as u64;
                if let Some(t) = s.ttft_s {
                    ttfts.push(t);
                }
                totals.push(s.total_s);
            }
            Terminal::Rejected => rejected += 1,
            Terminal::Cancelled => cancelled += 1,
            Terminal::Error | Terminal::Dropped => failed += 1,
        }
    }

    Ok(LoadReport {
        mode: match cfg.mode {
            LoadMode::Closed { users } => format!("closed users={users}"),
            LoadMode::Open { rate_rps } => format!("open rate={rate_rps}/s"),
        },
        scenario: cfg.scenario.as_str().to_string(),
        adaptive: cfg.adaptive,
        requests: cfg.requests,
        completed,
        rejected,
        cancelled,
        failed,
        retries: retries_total.load(Ordering::Relaxed),
        tokens,
        wall_s,
        tokens_per_s: if wall_s > 0.0 { tokens as f64 / wall_s } else { 0.0 },
        goodput_rps: if wall_s > 0.0 { completed as f64 / wall_s } else { 0.0 },
        ttft_ms: percentiles_ms(&mut ttfts),
        total_ms: percentiles_ms(&mut totals),
    })
}

/// `GET` a non-chunked route and return the body (shared by the metrics
/// and trace fetchers; both routes answer with `content-length` bodies).
fn http_get(addr: &str, path: &str, timeout: Duration) -> Result<String> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(timeout)).ok();
    let mut w = stream.try_clone().context("clone socket")?;
    write!(w, "GET {path} HTTP/1.1\r\nhost: {addr}\r\nconnection: close\r\n\r\n")?;
    w.flush()?;
    let mut r = BufReader::new(stream);
    let mut line = String::new();
    r.read_line(&mut line).context("read status line")?;
    anyhow::ensure!(
        line.split_whitespace().nth(1) == Some("200"),
        "GET {path} answered {line:?}"
    );
    let mut content_length = 0usize;
    loop {
        let mut l = String::new();
        if r.read_line(&mut l)? == 0 {
            anyhow::bail!("connection closed in response headers");
        }
        let l = l.trim_end().to_ascii_lowercase();
        if l.is_empty() {
            break;
        }
        if let Some(v) = l.strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    let mut buf = Vec::new();
    if content_length > 0 {
        buf.resize(content_length, 0);
        r.read_exact(&mut buf).context("read response body")?;
    } else {
        r.read_to_end(&mut buf).context("read response body")?;
    }
    Ok(String::from_utf8_lossy(&buf).into_owned())
}

/// `GET /metrics` and return the Prometheus page body (the smoke path
/// uses this to assert prefix-cache activity after a multiturn run).
pub fn fetch_metrics(addr: &str, timeout: Duration) -> Result<String> {
    http_get(addr, "/metrics", timeout)
}

/// `GET /debug/trace?last=N` and return the Chrome trace-event JSON
/// document (the loadgen CLI writes this to `--trace-out`; empty when
/// the server process never armed tracing).
pub fn fetch_trace(addr: &str, last: usize, timeout: Duration) -> Result<String> {
    http_get(addr, &format!("/debug/trace?last={last}"), timeout)
}

/// Value of a single-sample metric in a Prometheus text page.
pub fn metric_value(page: &str, name: &str) -> Option<f64> {
    page.lines().filter(|l| !l.starts_with('#')).find_map(|l| {
        let (n, v) = l.split_once(' ')?;
        if n == name {
            v.trim().parse().ok()
        } else {
            None
        }
    })
}

/// Client-side read shaping for global request index `i` under the
/// configured scenario.  Deterministic in `i` alone so reruns replay the
/// same storm.
pub fn stream_options_for(i: usize, cfg: &LoadConfig) -> StreamOptions {
    match cfg.scenario {
        Scenario::Oneshot | Scenario::Multiturn => StreamOptions::default(),
        Scenario::Slowreader => StreamOptions {
            // ~2ms per chunk read trickles a 32-token stream over tens of
            // milliseconds without making smoke runs crawl.
            read_delay: Some(Duration::from_millis(2)),
            hangup_after_tokens: None,
        },
        Scenario::Cancelstorm => StreamOptions {
            read_delay: None,
            // Bursts of three hangup clients (after 1, 2, 3 tokens), then
            // one patient reader — goodput stays nonzero by construction.
            hangup_after_tokens: match i % 4 {
                3 => None,
                k => Some(k + 1),
            },
        },
    }
}

/// One request, with transport failures folded into the sample.  Retries
/// (rejections and transport drops only — never a request the server
/// already worked on) back off exponentially with seeded jitter; returns
/// the final outcome and how many retries it took.
fn issue(i: usize, cfg: &LoadConfig) -> (StreamOutcome, usize) {
    let greq = request_for(i, cfg);
    let opts = stream_options_for(i, cfg);
    let mut rng = Rng::seed_from_u64(cfg.seed ^ 0x5265_7472 ^ (i as u64) << 20); // "Retr"
    let mut retries = 0usize;
    loop {
        let outcome = match stream_once_opts(&cfg.addr, &greq, cfg.timeout, opts) {
            Ok(o) => o,
            Err(e) => StreamOutcome {
                status: 0,
                terminal: Terminal::Dropped,
                tokens: Vec::new(),
                ttft_s: None,
                total_s: 0.0,
                done_data: None,
                error_body: Some(format!("{e:#}")),
                retry_after_s: None,
            },
        };
        let retryable = matches!(outcome.terminal, Terminal::Rejected | Terminal::Dropped);
        if !retryable || retries >= cfg.retries {
            return (outcome, retries);
        }
        // Jittered exponential backoff: base 10ms doubling per attempt,
        // +0..100% jitter, floored by the server's Retry-After on a 429.
        let base_ms = 10u64 << retries.min(6);
        let jitter_ms = rng.gen_range(base_ms as usize + 1) as u64;
        let server_floor_ms = outcome.retry_after_s.map(|s| s * 1000).unwrap_or(0);
        // Cap the wait so smoke runs stay fast even when the server
        // advertises a whole-second Retry-After.
        let wait_ms = (base_ms + jitter_ms).max(server_floor_ms).min(500);
        std::thread::sleep(Duration::from_millis(wait_ms));
        retries += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_known_samples() {
        let mut s: Vec<f64> = (1..=100).map(|i| i as f64 / 1000.0).collect();
        let p = percentiles_ms(&mut s);
        assert!((p.p50 - 50.0).abs() <= 2.0, "{}", p.p50);
        assert!((p.p95 - 95.0).abs() <= 2.0, "{}", p.p95);
        assert!((p.p99 - 99.0).abs() <= 2.0, "{}", p.p99);
        assert_eq!(percentiles_ms(&mut Vec::new()).p50, 0.0);
    }

    #[test]
    fn sse_event_block_parsing() {
        let (e, d) = parse_event(b"event: chunk\ndata: {\"tokens\":[1,2]}");
        assert_eq!(e, "chunk");
        assert_eq!(d, "{\"tokens\":[1,2]}");
        let (e, d) = parse_event(b"event: done\ndata: {}");
        assert_eq!(e, "done");
        assert_eq!(d, "{}");
    }

    #[test]
    fn request_for_cycles_prompts_and_carries_knobs() {
        let cfg = LoadConfig { gen_len: 7, seed: 9, adaptive: true, ..Default::default() };
        let a = request_for(0, &cfg);
        let b = request_for(PROMPTS.len(), &cfg);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.gen_len, 7);
        assert_eq!(a.seed, 9);
        assert!(a.adaptive, "adaptive knob must reach the wire request");
        assert_ne!(request_for(1, &cfg).prompt, a.prompt);
    }

    #[test]
    fn multiturn_schedule_is_deterministic_and_heavy_tailed() {
        let slots: Vec<(u64, usize)> = (0..64).map(|i| multiturn_slot(i, 7)).collect();
        let again: Vec<(u64, usize)> = (0..64).map(|i| multiturn_slot(i, 7)).collect();
        assert_eq!(slots, again, "schedule must be a pure function of (i, seed)");
        // Turn indexes stay under the clamp and restart per session.
        for w in slots.windows(2) {
            assert!(w[0].1 < 8);
            if w[1].0 == w[0].0 {
                assert_eq!(w[1].1, w[0].1 + 1);
            } else {
                assert_eq!(w[1].1, 0);
            }
        }
        // Heavy tail: some conversation runs past one turn, and more than
        // one distinct session appears.
        assert!(slots.iter().any(|&(_, t)| t >= 1));
        assert!(slots.iter().map(|&(s, _)| s).collect::<std::collections::HashSet<_>>().len() > 1);
        // Different seeds reshuffle the schedule.
        assert_ne!(slots, (0..64).map(|i| multiturn_slot(i, 8)).collect::<Vec<_>>());
    }

    #[test]
    fn multiturn_requests_share_the_system_prompt_and_carry_sessions() {
        let cfg =
            LoadConfig { scenario: Scenario::Multiturn, seed: 3, ..Default::default() };
        let mut openers = 0;
        for i in 0..32 {
            let (sid, turn) = multiturn_slot(i, cfg.seed);
            let req = request_for(i, &cfg);
            assert_eq!(req.session, Some(sid), "every turn must carry its session id");
            if turn == 0 {
                openers += 1;
                assert!(
                    req.prompt.starts_with(SYSTEM_PROMPT.as_bytes()),
                    "conversation openers must share the system prefix"
                );
            } else {
                assert!(!req.prompt.starts_with(SYSTEM_PROMPT.as_bytes()));
            }
        }
        assert!(openers > 1, "need multiple conversations to share the prefix");
        assert!(SYSTEM_PROMPT.len() >= 64, "system prompt must span >= 4 full KV pages");
    }

    #[test]
    fn prometheus_metric_values_parse() {
        let page = "# HELP x h\n# TYPE x gauge\nx 4\nspeq_prefix_cache_hit_tokens_total 128\n";
        assert_eq!(metric_value(page, "x"), Some(4.0));
        assert_eq!(metric_value(page, "speq_prefix_cache_hit_tokens_total"), Some(128.0));
        assert_eq!(metric_value(page, "missing"), None);
    }

    #[test]
    fn bench_json_line_is_parseable() {
        let r = LoadReport {
            mode: "closed users=4".into(),
            scenario: "oneshot".into(),
            adaptive: true,
            requests: 8,
            completed: 8,
            rejected: 0,
            cancelled: 0,
            failed: 0,
            retries: 3,
            tokens: 256,
            wall_s: 1.5,
            tokens_per_s: 170.6,
            goodput_rps: 5.33,
            ttft_ms: Percentiles { p50: 10.0, p95: 20.0, p99: 30.0 },
            total_ms: Percentiles { p50: 100.0, p95: 200.0, p99: 300.0 },
        };
        let line = r.bench_json();
        let json_part = line.strip_prefix("BENCH_JSON ").unwrap();
        let v = crate::util::json::parse(json_part).unwrap();
        assert_eq!(v.get("group").unwrap().as_str(), Some("net_loadgen"));
        assert_eq!(v.get("scenario").unwrap().as_str(), Some("oneshot"));
        assert_eq!(v.get("adaptive").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("completed").unwrap().as_usize(), Some(8));
        assert_eq!(v.get("retries").unwrap().as_usize(), Some(3));
        assert!(v.get("tokens_per_sec").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn scenario_names_round_trip() {
        for s in [
            Scenario::Oneshot,
            Scenario::Multiturn,
            Scenario::Slowreader,
            Scenario::Cancelstorm,
        ] {
            assert_eq!(Scenario::from_name(s.as_str()), Some(s));
        }
        assert_eq!(Scenario::from_name("chaos"), None);
    }

    #[test]
    fn cancelstorm_schedule_keeps_a_patient_reader_per_burst() {
        let cfg = LoadConfig { scenario: Scenario::Cancelstorm, ..Default::default() };
        let mut patient = 0;
        let mut hangups = 0;
        for i in 0..16 {
            let o = stream_options_for(i, &cfg);
            assert!(o.read_delay.is_none());
            match o.hangup_after_tokens {
                None => patient += 1,
                Some(k) => {
                    hangups += 1;
                    assert!((1..=3).contains(&k), "hangup point {k} out of burst range");
                }
            }
        }
        assert_eq!(patient, 4, "every fourth request reads to completion");
        assert_eq!(hangups, 12);
        // Deterministic: same index, same shape.
        assert_eq!(
            stream_options_for(5, &cfg).hangup_after_tokens,
            stream_options_for(5, &cfg).hangup_after_tokens
        );
    }

    #[test]
    fn slowreader_trickles_and_never_hangs_up() {
        let cfg = LoadConfig { scenario: Scenario::Slowreader, ..Default::default() };
        for i in 0..8 {
            let o = stream_options_for(i, &cfg);
            assert!(o.read_delay.is_some());
            assert!(o.hangup_after_tokens.is_none());
        }
    }
}
