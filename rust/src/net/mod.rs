//! `speq::net` — the std-only HTTP/1.1 serving front end.
//!
//! Turns the in-process [`coordinator`] into a network service, with no
//! dependencies beyond `std::net` (consistent with the vendored-offline
//! workspace):
//!
//! * [`http`] — HTTP/1.1 request parsing (Content-Length framing, header
//!   and body size limits, keep-alive), response writing, and chunked
//!   transfer encoding for streaming.
//! * [`api`] — the JSON request/response schema shared by both generation
//!   routes and the SSE event assembly; byte-level tokens travel through
//!   the streaming-safe escaper (`util::json::escape_bytes`), so chunks
//!   may split multi-byte UTF-8 sequences without corrupting the stream.
//! * [`server`] — [`NetServer`]: accept loop + connection threads,
//!   routing (`POST /v1/generate`, `POST /v1/stream` (SSE),
//!   `GET /healthz`, `GET /metrics`), admission control (bounded queue →
//!   `429 + Retry-After`), per-request deadlines and client-disconnect
//!   cancellation propagated into the scheduler, and graceful shutdown
//!   (stop accepting → drain in-flight sequences → join connections).
//! * [`metrics`] — per-request latency histograms (TTFT, inter-token,
//!   total) and the Prometheus text exposition combining them with the
//!   coordinator's counters.
//! * [`loadgen`] — a closed-loop / open-loop (Poisson) load-generator
//!   client driving the server over real sockets, reporting tokens/sec,
//!   goodput, and p50/p95/p99 TTFT + total latency with `BENCH_JSON`
//!   output (the `speq loadgen` CLI subcommand).
//!
//! Determinism contract: a request over HTTP produces the exact token
//! bytes of the equivalent offline `Engine::generate_spec` call — the
//! front end adds transport, never touches generation (asserted by
//! `rust/tests/integration_net.rs`).
//!
//! [`coordinator`]: crate::coordinator

pub mod api;
pub mod http;
pub mod loadgen;
pub mod metrics;
pub mod server;

pub use api::GenerateRequest;
pub use loadgen::{LoadConfig, LoadMode, LoadReport, Scenario, StreamOptions};
pub use metrics::{LatencyHistogram, NetMetrics};
pub use server::{NetConfig, NetServer};
