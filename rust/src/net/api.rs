//! JSON request/response schema for the serving API, plus SSE event
//! assembly.  Built on [`util::json`]; token payloads use the
//! streaming-safe byte escaper ([`json::escape_bytes`]) because tokens are
//! *bytes* and a streamed chunk can split multi-byte UTF-8 sequences.
//!
//! `POST /v1/generate` and `POST /v1/stream` share one request schema:
//!
//! ```json
//! {
//!   "prompt": "Q: ...",        // required; chars ≤ U+00FF map to bytes
//!   "gen_len": 64,
//!   "mode": "spec" | "ar",
//!   "temperature": 0.0,
//!   "seed": 0,
//!   "max_draft": 16,
//!   "gamma": 0.6,
//!   "adaptive": false,          // per-sequence adaptive draft-length controller
//!   "priority": "interactive" | "batch",
//!   "session": 17,              // optional multi-turn conversation id
//!   "deadline_ms": 2000         // optional per-request deadline
//! }
//! ```
//!
//! [`util::json`]: crate::util::json

use std::time::{Duration, Instant};

use crate::coordinator::{Mode, Priority, ResponseBody, SubmitParams};
use crate::model::SamplingParams;
use crate::util::json::{self, Value};

/// A parsed generation request (defaults match [`SubmitParams::default`],
/// so an HTTP request and a library `submit` with the same knobs produce
/// bit-identical generations).
#[derive(Debug, Clone)]
pub struct GenerateRequest {
    pub prompt: Vec<u8>,
    pub gen_len: usize,
    pub mode: Mode,
    pub temperature: f32,
    pub seed: u64,
    pub max_draft: usize,
    pub gamma: f32,
    pub adaptive: bool,
    pub priority: Priority,
    pub session: Option<u64>,
    pub deadline_ms: Option<u64>,
}

impl Default for GenerateRequest {
    fn default() -> Self {
        let p = SubmitParams::default();
        Self {
            prompt: Vec::new(),
            gen_len: p.gen_len,
            mode: p.mode,
            temperature: 0.0,
            seed: 0,
            max_draft: p.max_draft,
            gamma: p.gamma,
            adaptive: p.adaptive,
            priority: p.priority,
            session: None,
            deadline_ms: None,
        }
    }
}

impl GenerateRequest {
    /// Parse a request body; `Err` carries a client-facing message (400).
    pub fn from_json(text: &str) -> Result<Self, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        if v.as_obj().is_none() {
            return Err("request body must be a JSON object".into());
        }
        let mut req = GenerateRequest::default();
        let prompt = v
            .get("prompt")
            .and_then(Value::as_str)
            .ok_or_else(|| "missing required string field \"prompt\"".to_string())?;
        req.prompt = prompt_bytes(prompt).ok_or_else(|| {
            "\"prompt\" chars must be ≤ U+00FF (byte tokens; escape raw UTF-8 bytes as \\u00XX)"
                .to_string()
        })?;
        if req.prompt.is_empty() {
            return Err("\"prompt\" must be non-empty".into());
        }
        if let Some(n) = v.get("gen_len") {
            req.gen_len = n.as_usize().ok_or("\"gen_len\" must be a number")?;
        }
        if let Some(m) = v.get("mode") {
            req.mode = match m.as_str() {
                Some("spec") | Some("speculative") => Mode::Speculative,
                Some("ar") | Some("autoregressive") => Mode::Autoregressive,
                _ => return Err("\"mode\" must be \"spec\" or \"ar\"".into()),
            };
        }
        if let Some(t) = v.get("temperature") {
            req.temperature = t.as_f64().ok_or("\"temperature\" must be a number")? as f32;
        }
        if let Some(s) = v.get("seed") {
            req.seed = s.as_f64().ok_or("\"seed\" must be a number")? as u64;
        }
        if let Some(d) = v.get("max_draft") {
            req.max_draft = d.as_usize().ok_or("\"max_draft\" must be a number")?;
        }
        if let Some(g) = v.get("gamma") {
            req.gamma = g.as_f64().ok_or("\"gamma\" must be a number")? as f32;
        }
        if let Some(a) = v.get("adaptive") {
            req.adaptive = a.as_bool().ok_or("\"adaptive\" must be a boolean")?;
        }
        if let Some(p) = v.get("priority") {
            req.priority = match p.as_str() {
                Some("interactive") => Priority::Interactive,
                Some("batch") => Priority::Batch,
                _ => return Err("\"priority\" must be \"interactive\" or \"batch\"".into()),
            };
        }
        if let Some(s) = v.get("session") {
            req.session = Some(s.as_f64().ok_or("\"session\" must be a number")? as u64);
        }
        if let Some(d) = v.get("deadline_ms") {
            req.deadline_ms = Some(d.as_f64().ok_or("\"deadline_ms\" must be a number")? as u64);
        }
        Ok(req)
    }

    /// Serialize for the wire (the loadgen client and tests).
    pub fn to_json(&self) -> String {
        let mut body = String::from("{\"prompt\":");
        body.push_str(&json::escape_bytes(&self.prompt));
        body.push_str(&format!(
            ",\"gen_len\":{},\"mode\":\"{}\",\"temperature\":{},\"seed\":{},\"max_draft\":{},\"gamma\":{},\"adaptive\":{},\"priority\":\"{}\"",
            self.gen_len,
            match self.mode {
                Mode::Speculative => "spec",
                Mode::Autoregressive => "ar",
            },
            self.temperature,
            self.seed,
            self.max_draft,
            self.gamma,
            self.adaptive,
            match self.priority {
                Priority::Interactive => "interactive",
                Priority::Batch => "batch",
            },
        ));
        if let Some(s) = self.session {
            body.push_str(&format!(",\"session\":{s}"));
        }
        if let Some(d) = self.deadline_ms {
            body.push_str(&format!(",\"deadline_ms\":{d}"));
        }
        body.push('}');
        body
    }

    /// The coordinator submission this request maps to.  `deadline_ms`
    /// beats the server-wide default.
    pub fn submit_params(&self, default_deadline: Option<Duration>) -> SubmitParams {
        let deadline = self
            .deadline_ms
            .map(Duration::from_millis)
            .or(default_deadline)
            .map(|d| Instant::now() + d);
        SubmitParams {
            gen_len: self.gen_len,
            mode: self.mode,
            priority: self.priority,
            sampling: SamplingParams { temperature: self.temperature, seed: self.seed },
            session: self.session,
            max_draft: self.max_draft,
            gamma: self.gamma,
            adaptive: self.adaptive,
            deadline,
        }
    }
}

/// Decode a JSON prompt string to byte tokens via the Latin-1 mapping —
/// the exact inverse of [`json::escape_bytes`], so any byte sequence can
/// be expressed and the decoding is *unambiguous* (the same character
/// always yields the same byte, regardless of the rest of the string).
/// Chars above U+00FF return `None` and are rejected as a 400: clients
/// sending raw UTF-8 text must escape it per byte (`\u00XX`), exactly as
/// the server's own `text` fields do.
pub fn prompt_bytes(s: &str) -> Option<Vec<u8>> {
    json::bytes_from_escaped(s)
}

/// `data:` payload for a `chunk` SSE event: the token byte values plus
/// their escaper-rendered text form.
pub fn chunk_event_data(tokens: &[u8]) -> String {
    let toks: Vec<String> = tokens.iter().map(|b| b.to_string()).collect();
    format!("{{\"tokens\":[{}],\"text\":{}}}", toks.join(","), json::escape_bytes(tokens))
}

/// `data:` payload for the terminal `done` SSE event (also the
/// `/v1/generate` response body): the full token stream plus accept-rate
/// and traffic statistics.
///
/// `accept_rate` is `0.0` for sessions that drafted nothing (pure AR
/// requests): zero drafted tokens is zero accept-rate evidence, not a
/// perfect score — see `SpecTrace::accept_rate`.
pub fn done_data(
    id: u64,
    body: &ResponseBody,
    ttft_ms: Option<f64>,
    traffic: (f64, f64, f64),
) -> String {
    let (bpt_draft, bpt_full, ratio) = traffic;
    let toks: Vec<String> = body.tokens.iter().map(|b| b.to_string()).collect();
    let mut out = format!(
        "{{\"id\":{id},\"tokens\":[{}],\"text\":{},\"tokens_total\":{},\"accept_rate\":{:.6},\"mean_accept_len\":{:.4},\"draft_steps\":{},\"verify_passes\":{},\"latency_ms\":{:.3},\"exec_ms\":{:.3},\"worker\":{}",
        toks.join(","),
        json::escape_bytes(&body.tokens),
        body.tokens.len(),
        finite(body.trace.accept_rate()),
        finite(body.trace.mean_accept_len()),
        body.trace.draft_steps(),
        body.trace.verify_passes(),
        body.latency_s * 1e3,
        body.exec_s * 1e3,
        body.worker,
    );
    if let Some(t) = ttft_ms {
        out.push_str(&format!(",\"ttft_ms\":{t:.3}"));
    }
    // Per-phase latency attribution: the five buckets sum to latency_ms
    // by construction (see `coordinator::RequestPhases`).
    out.push_str(&format!(
        ",\"queue_wait_ms\":{:.3},\"prefill_ms\":{:.3},\"draft_ms\":{:.3},\"verify_ms\":{:.3},\"stall_ms\":{:.3}",
        finite(body.phases.queue_wait_s * 1e3),
        finite(body.phases.prefill_s * 1e3),
        finite(body.phases.draft_s * 1e3),
        finite(body.phases.verify_s * 1e3),
        finite(body.phases.stall_s * 1e3),
    ));
    out.push_str(&format!(
        ",\"bytes_per_token_draft\":{:.1},\"bytes_per_token_full\":{:.1},\"draft_traffic_ratio\":{:.4}}}",
        finite(bpt_draft),
        finite(bpt_full),
        finite(ratio)
    ));
    out
}

/// `data:` payload for an error (terminal) event / error response body.
pub fn error_data(message: &str) -> String {
    format!("{{\"error\":{}}}", json::escape_bytes(message.as_bytes()))
}

fn finite(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

/// Frame one Server-Sent Event (`event:` + single-line `data:`).  Payloads
/// produced by this module never contain raw newlines (the byte escaper
/// guarantees it), so one `data:` line always suffices.
pub fn sse_event(event: &str, data: &str) -> Vec<u8> {
    debug_assert!(!data.contains('\n'), "SSE data must be single-line");
    format!("event: {event}\ndata: {data}\n\n").into_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_full_request() {
        let r = GenerateRequest::from_json(
            r#"{"prompt":"hi there","gen_len":32,"mode":"ar","temperature":0.5,"seed":7,
                "max_draft":8,"gamma":0.4,"adaptive":true,"priority":"batch","session":3,"deadline_ms":250}"#,
        )
        .unwrap();
        assert_eq!(r.prompt, b"hi there");
        assert_eq!(r.gen_len, 32);
        assert_eq!(r.mode, Mode::Autoregressive);
        assert_eq!(r.seed, 7);
        assert_eq!(r.max_draft, 8);
        assert!(r.adaptive);
        assert_eq!(r.priority, Priority::Batch);
        assert_eq!(r.session, Some(3));
        assert_eq!(r.deadline_ms, Some(250));
    }

    #[test]
    fn defaults_match_submit_params_defaults() {
        let r = GenerateRequest::from_json(r#"{"prompt":"x"}"#).unwrap();
        let d = SubmitParams::default();
        assert_eq!(r.gen_len, d.gen_len);
        assert_eq!(r.max_draft, d.max_draft);
        assert_eq!(r.gamma, d.gamma);
        assert_eq!(r.adaptive, d.adaptive);
        assert_eq!(r.mode, d.mode);
        let p = r.submit_params(None);
        assert!(p.deadline.is_none());
        assert!(p.sampling.is_greedy());
    }

    #[test]
    fn wire_roundtrip_preserves_every_field() {
        let mut req = GenerateRequest::default();
        req.prompt = vec![0u8, b'a', 0xff, b'\n'];
        req.gen_len = 17;
        req.mode = Mode::Autoregressive;
        req.seed = 42;
        req.adaptive = true;
        req.session = Some(9);
        req.deadline_ms = Some(125);
        let back = GenerateRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.prompt, req.prompt);
        assert_eq!(back.gen_len, 17);
        assert_eq!(back.mode, Mode::Autoregressive);
        assert_eq!(back.seed, 42);
        assert!(back.adaptive);
        assert_eq!(back.session, Some(9));
        assert_eq!(back.deadline_ms, Some(125));
    }

    #[test]
    fn missing_or_empty_prompt_is_rejected() {
        assert!(GenerateRequest::from_json(r#"{}"#).is_err());
        assert!(GenerateRequest::from_json(r#"{"prompt":""}"#).is_err());
        assert!(GenerateRequest::from_json("not json").is_err());
        assert!(GenerateRequest::from_json(r#"[1,2]"#).is_err());
    }

    #[test]
    fn prompt_decoding_is_unambiguous() {
        // Latin-1 range decodes to one byte per char ...
        let r = GenerateRequest::from_json("{\"prompt\":\"caf\\u00e9\"}").unwrap();
        assert_eq!(r.prompt, vec![b'c', b'a', b'f', 0xe9]);
        // ... and chars above U+00FF are rejected, never silently
        // re-encoded (the same char must always map to the same byte).
        let e = GenerateRequest::from_json("{\"prompt\":\"caf\\u00e9 \\ud83d\\ude00\"}")
            .unwrap_err();
        assert!(e.contains("U+00FF"), "{e}");
    }

    #[test]
    fn chunk_event_data_is_parseable_and_lossless() {
        let tokens = vec![72u8, 0, 10, 255];
        let data = chunk_event_data(&tokens);
        let v = crate::util::json::parse(&data).unwrap();
        let nums: Vec<u8> = v
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|n| n.as_usize().unwrap() as u8)
            .collect();
        assert_eq!(nums, tokens);
        let text = v.get("text").unwrap().as_str().unwrap();
        assert_eq!(crate::util::json::bytes_from_escaped(text).unwrap(), tokens);
        assert!(!data.contains('\n'));
    }

    #[test]
    fn done_data_carries_phase_breakdown_summing_to_latency() {
        use crate::coordinator::RequestPhases;
        use crate::specdec::SpecTrace;
        let phases = RequestPhases {
            queue_wait_s: 0.010,
            prefill_s: 0.020,
            draft_s: 0.030,
            verify_s: 0.025,
            stall_s: 0.015,
        };
        let body = ResponseBody {
            tokens: vec![1, 2, 3],
            trace: SpecTrace { iterations: vec![], produced: 3, prompt_len: 4 },
            latency_s: phases.total_s(),
            exec_s: phases.total_s() - phases.queue_wait_s,
            phases,
            worker: 0,
        };
        let data = done_data(7, &body, Some(12.0), (0.0, 0.0, 0.0));
        let v = crate::util::json::parse(&data).unwrap();
        let ms = |k: &str| v.get(k).unwrap().as_f64().unwrap();
        let sum = ms("queue_wait_ms")
            + ms("prefill_ms")
            + ms("draft_ms")
            + ms("verify_ms")
            + ms("stall_ms");
        let latency = ms("latency_ms");
        assert!((sum - latency).abs() <= 0.05 * latency, "{sum} vs {latency}");
        assert!(!data.contains('\n'));
    }

    #[test]
    fn sse_event_frames() {
        let e = sse_event("chunk", "{\"tokens\":[1]}");
        assert_eq!(e, b"event: chunk\ndata: {\"tokens\":[1]}\n\n");
    }

    #[test]
    fn error_data_escapes_newlines() {
        let d = error_data("bad\nthing");
        assert!(!d.contains('\n'));
        let v = crate::util::json::parse(&d).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("bad\nthing"));
    }
}
