//! Serving-front-end metrics: per-request latency histograms (TTFT,
//! inter-token, total) and the Prometheus text exposition for
//! `GET /metrics`, combining the net layer's own observations with the
//! coordinator's [`MetricsSnapshot`] counters.

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::MetricsSnapshot;

/// Histogram bucket upper bounds, seconds.  Log-spaced from 0.5 ms to 30 s
/// — wide enough to cover TTFT on a warm batch and multi-second total
/// latencies under load; the implicit `+Inf` bucket catches the rest.
pub const LATENCY_BUCKETS_S: &[f64] = &[
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
];

/// Lock-free fixed-bucket latency histogram (Prometheus semantics: the
/// rendered `_bucket` series are cumulative, `_sum`/`_count` included).
pub struct LatencyHistogram {
    /// Per-bucket (non-cumulative) counts; last entry is the `+Inf` bucket.
    buckets: Vec<AtomicU64>,
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..=LATENCY_BUCKETS_S.len()).map(|_| AtomicU64::new(0)).collect(),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Record one observation, in seconds.
    ///
    /// Non-finite samples are **dropped** (uncounted): a NaN must never
    /// reach the bucket search or `_sum`, and counting it as zero would
    /// silently skew the distribution.  Negative samples (clock
    /// adjustment artifacts) clamp to zero and count.
    pub fn observe(&self, seconds: f64) {
        if !seconds.is_finite() {
            return;
        }
        let s = seconds.max(0.0);
        let idx = LATENCY_BUCKETS_S
            .iter()
            .position(|&le| s <= le)
            .unwrap_or(LATENCY_BUCKETS_S.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add((s * 1e6) as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of observations, seconds.
    pub fn sum_s(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Append the Prometheus exposition for this histogram.
    pub fn render(&self, name: &str, help: &str, out: &mut String) {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} histogram");
        let mut cumulative = 0u64;
        for (i, &le) in LATENCY_BUCKETS_S.iter().enumerate() {
            cumulative += self.buckets[i].load(Ordering::Relaxed);
            let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cumulative}");
        }
        cumulative += self.buckets[LATENCY_BUCKETS_S.len()].load(Ordering::Relaxed);
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cumulative}");
        let _ = writeln!(out, "{name}_sum {}", self.sum_s());
        let _ = writeln!(out, "{name}_count {}", self.count());
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// The front end's own metric sink, alongside the coordinator's.
pub struct NetMetrics {
    /// Time to first streamed token chunk, per request.
    pub ttft: LatencyHistogram,
    /// Per-token gap between streamed chunks (chunk gap divided by the
    /// tokens it carried), after the first chunk.
    pub inter_token: LatencyHistogram,
    /// Total request latency (submit to terminal event), per request.
    pub total: LatencyHistogram,
    /// Per-request latency attribution, from the completion body's
    /// [`RequestPhases`]: time queued before batch admission.
    ///
    /// [`RequestPhases`]: crate::coordinator::RequestPhases
    pub phase_queue_wait: LatencyHistogram,
    /// Attribution: time inside batched prefill ops.
    pub phase_prefill: LatencyHistogram,
    /// Attribution: time inside batched quantized draft ops.
    pub phase_draft: LatencyHistogram,
    /// Attribution: time inside batched verification / full-decode ops.
    pub phase_verify: LatencyHistogram,
    /// Attribution: admitted wall time outside any engine op (scheduler
    /// bookkeeping, waiting on co-batched sequences).
    pub phase_stall: LatencyHistogram,
    /// Wall time spent writing SSE chunks to the client socket (overlaps
    /// the phases above; measured in the net layer, not the scheduler).
    pub phase_sse_write: LatencyHistogram,
    /// HTTP requests parsed off sockets (any route, any outcome).
    pub http_requests: AtomicU64,
    /// Requests answered 429 by admission control.
    pub http_throttled: AtomicU64,
    /// Connections accepted.
    pub connections: AtomicU64,
}

impl NetMetrics {
    pub fn new() -> Self {
        Self {
            ttft: LatencyHistogram::new(),
            inter_token: LatencyHistogram::new(),
            total: LatencyHistogram::new(),
            phase_queue_wait: LatencyHistogram::new(),
            phase_prefill: LatencyHistogram::new(),
            phase_draft: LatencyHistogram::new(),
            phase_verify: LatencyHistogram::new(),
            phase_stall: LatencyHistogram::new(),
            phase_sse_write: LatencyHistogram::new(),
            http_requests: AtomicU64::new(0),
            http_throttled: AtomicU64::new(0),
            connections: AtomicU64::new(0),
        }
    }

    /// The full `/metrics` page: front-end histograms + HTTP counters +
    /// the coordinator's serving counters and traffic accounting.
    pub fn render_prometheus(&self, snap: &MetricsSnapshot, queue_depth: usize) -> String {
        let mut out = String::with_capacity(4096);
        let mut counter = |name: &str, help: &str, v: f64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(
                out,
                "# TYPE {name} {}",
                if name.ends_with("_total") { "counter" } else { "gauge" }
            );
            let _ = writeln!(out, "{name} {v}");
        };
        counter(
            "speq_requests_submitted_total",
            "Generation requests accepted by submit().",
            snap.submitted as f64,
        );
        counter(
            "speq_requests_completed_total",
            "Generations that ran to completion.",
            snap.completed as f64,
        );
        counter(
            "speq_requests_rejected_total",
            "Submissions rejected by queue backpressure.",
            snap.rejected as f64,
        );
        counter(
            "speq_requests_failed_total",
            "Generations that errored (admission or engine step).",
            snap.failed as f64,
        );
        counter(
            "speq_requests_cancelled_total",
            "Requests retired between steps (deadline or client cancel).",
            snap.cancelled as f64,
        );
        counter(
            "speq_requests_quarantined_total",
            "Requests evicted from a live batch by blast-radius isolation.",
            snap.quarantined as f64,
        );
        counter(
            "speq_faults_injected_total",
            "Faults fired by the configured injection plan.",
            snap.faults_injected as f64,
        );
        counter(
            "speq_faults_recovered_total",
            "Fault events the serving stack contained and recovered from.",
            snap.faults_recovered as f64,
        );
        counter(
            "speq_degradation_level",
            "Graceful-degradation rung: 0 healthy, 1 evicting prefix cache, 2 speculation capped, 3 shedding admissions.",
            snap.degradation_level as f64,
        );
        counter(
            "speq_tokens_generated_total",
            "Tokens generated across all completed requests.",
            snap.tokens as f64,
        );
        counter(
            "speq_draft_steps_total",
            "Quantized draft decode steps.",
            snap.draft_steps as f64,
        );
        counter(
            "speq_verify_passes_total",
            "Full-precision verification passes.",
            snap.verify_passes as f64,
        );
        counter(
            "speq_http_requests_total",
            "HTTP requests parsed by the front end.",
            self.http_requests.load(Ordering::Relaxed) as f64,
        );
        counter(
            "speq_http_throttled_total",
            "HTTP requests answered 429 by admission control.",
            self.http_throttled.load(Ordering::Relaxed) as f64,
        );
        counter(
            "speq_http_connections_total",
            "TCP connections accepted.",
            self.connections.load(Ordering::Relaxed) as f64,
        );
        counter("speq_queue_depth", "Requests waiting in the admission queue.", queue_depth as f64);
        counter(
            "speq_batch_occupancy_mean",
            "Mean sequences per scheduler engine step.",
            snap.batch_occupancy_mean,
        );
        counter(
            "speq_tokens_per_second",
            "Generated tokens per wall-clock second since start.",
            snap.tokens_per_s,
        );
        counter(
            "speq_bytes_per_token_draft",
            "Draft-pass weight bytes streamed per decoded token.",
            snap.bytes_per_token_draft,
        );
        counter(
            "speq_bytes_per_token_full",
            "Full-pass weight bytes streamed per decoded token.",
            snap.bytes_per_token_full,
        );
        counter(
            "speq_draft_traffic_ratio",
            "Measured quarter-to-all ratio (draft/full bytes per token).",
            snap.draft_traffic_ratio,
        );
        counter(
            "speq_kv_pages_allocated",
            "KV pages held by live sequences or the prefix cache.",
            snap.kv_pages_allocated as f64,
        );
        counter(
            "speq_kv_pages_budget",
            "Configured KV page budget (0 = unbounded).",
            snap.kv.pages_budget as f64,
        );
        counter(
            "speq_kv_pages_shared",
            "KV pages mapped by more than one owner (prefix sharing).",
            snap.kv_pages_shared as f64,
        );
        counter(
            "speq_kv_cow_copies_total",
            "Pages copied on write into a shared KV page.",
            snap.kv_cow_copies as f64,
        );
        counter(
            "speq_prefix_cache_hit_tokens_total",
            "Prompt tokens served from the prefix cache (prefill skipped).",
            snap.prefix_cache_hit_tokens as f64,
        );
        counter(
            "speq_prefix_cache_miss_tokens_total",
            "Prompt tokens computed by the full prefill pass.",
            snap.prefix_cache_miss_tokens as f64,
        );
        counter(
            "speq_prefix_cache_hit_rate",
            "Fraction of prefill tokens served from the prefix cache.",
            snap.prefix_cache_hit_rate,
        );
        counter(
            "speq_adaptive_sessions",
            "Active sequences running the adaptive draft-length controller.",
            snap.adaptive_sessions as f64,
        );
        counter(
            "speq_adaptive_draft_len",
            "Mean live draft budget across adaptive sequences, last step.",
            snap.adaptive_draft_len_mean,
        );
        counter(
            "speq_adaptive_accept_rate",
            "Mean EWMA accept-rate estimate across adaptive sequences.",
            snap.adaptive_accept_rate_mean,
        );
        self.ttft.render(
            "speq_ttft_seconds",
            "Time from HTTP submit to the first streamed token chunk.",
            &mut out,
        );
        self.inter_token.render(
            "speq_inter_token_seconds",
            "Per-token gap between streamed chunks after the first.",
            &mut out,
        );
        self.total.render(
            "speq_request_duration_seconds",
            "Total request latency, submit to terminal event.",
            &mut out,
        );
        self.phase_queue_wait.render(
            "speq_phase_queue_wait_seconds",
            "Per-request latency attribution: queued before batch admission.",
            &mut out,
        );
        self.phase_prefill.render(
            "speq_phase_prefill_seconds",
            "Per-request latency attribution: batched prefill ops.",
            &mut out,
        );
        self.phase_draft.render(
            "speq_phase_draft_seconds",
            "Per-request latency attribution: batched quantized draft ops.",
            &mut out,
        );
        self.phase_verify.render(
            "speq_phase_verify_seconds",
            "Per-request latency attribution: batched verify / full-decode ops.",
            &mut out,
        );
        self.phase_stall.render(
            "speq_phase_stall_seconds",
            "Per-request latency attribution: admitted wall time outside engine ops.",
            &mut out,
        );
        self.phase_sse_write.render(
            "speq_phase_sse_write_seconds",
            "Wall time writing SSE chunks to the client socket (overlaps other phases).",
            &mut out,
        );
        out
    }

    /// Feed one completed request's scheduler-side phase attribution into
    /// the histograms (`sse_write` is observed by the stream handler,
    /// which is the only place that time exists).
    pub fn observe_phases(&self, p: &crate::coordinator::RequestPhases) {
        self.phase_queue_wait.observe(p.queue_wait_s);
        self.phase_prefill.observe(p.prefill_s);
        self.phase_draft.observe(p.draft_s);
        self.phase_verify.observe(p.verify_s);
        self.phase_stall.observe(p.stall_s);
    }
}

impl Default for NetMetrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;

    #[test]
    fn observations_land_in_the_right_buckets() {
        let h = LatencyHistogram::new();
        h.observe(0.0004); // le 0.0005
        h.observe(0.003); // le 0.005
        h.observe(120.0); // +Inf
        assert_eq!(h.count(), 3);
        let mut out = String::new();
        h.render("x_seconds", "help", &mut out);
        assert!(out.contains("x_seconds_bucket{le=\"0.0005\"} 1"));
        // Cumulative: 0.005 bucket includes the 0.0005 one.
        assert!(out.contains("x_seconds_bucket{le=\"0.005\"} 2"));
        assert!(out.contains("x_seconds_bucket{le=\"+Inf\"} 3"));
        assert!(out.contains("x_seconds_count 3"));
    }

    #[test]
    fn negative_observations_clamp_and_non_finite_are_dropped() {
        let h = LatencyHistogram::new();
        h.observe(-1.0); // clamps to 0, counts
        h.observe(f64::NAN); // dropped
        h.observe(f64::INFINITY); // dropped
        h.observe(f64::NEG_INFINITY); // dropped
        assert_eq!(h.count(), 1);
        assert_eq!(h.sum_s(), 0.0);
        // The rendered exposition must stay numeric: no NaN in _sum, and
        // the single clamped sample lands in the smallest bucket.
        let mut out = String::new();
        h.render("x_seconds", "help", &mut out);
        assert!(out.contains("x_seconds_bucket{le=\"0.0005\"} 1"));
        assert!(out.contains("x_seconds_sum 0"));
        assert!(!out.contains("NaN"));
    }

    #[test]
    fn exposition_includes_coordinator_counters_and_histograms() {
        let m = Metrics::new();
        let phases = crate::coordinator::RequestPhases {
            queue_wait_s: 0.01,
            prefill_s: 0.01,
            draft_s: 0.01,
            verify_s: 0.01,
            stall_s: 0.01,
        };
        m.record_completion(10, 4, 2, 0.05, 0.04, &phases);
        let net = NetMetrics::new();
        net.ttft.observe(0.012);
        net.total.observe(0.05);
        net.observe_phases(&phases);
        net.phase_sse_write.observe(0.002);
        let page = net.render_prometheus(&m.snapshot(), 3);
        assert!(page.contains("speq_requests_completed_total 1"));
        assert!(page.contains("speq_tokens_generated_total 10"));
        assert!(page.contains("speq_queue_depth 3"));
        assert!(page.contains("# TYPE speq_ttft_seconds histogram"));
        assert!(page.contains("speq_ttft_seconds_count 1"));
        assert!(page.contains("speq_request_duration_seconds_count 1"));
        assert!(page.contains("# TYPE speq_phase_queue_wait_seconds histogram"));
        assert!(page.contains("speq_phase_queue_wait_seconds_count 1"));
        assert!(page.contains("speq_phase_prefill_seconds_count 1"));
        assert!(page.contains("speq_phase_draft_seconds_count 1"));
        assert!(page.contains("speq_phase_verify_seconds_count 1"));
        assert!(page.contains("speq_phase_stall_seconds_count 1"));
        assert!(page.contains("speq_phase_sse_write_seconds_count 1"));
        assert!(page.contains("# TYPE speq_requests_completed_total counter"));
        assert!(page.contains("# TYPE speq_queue_depth gauge"));
    }

    #[test]
    fn exposition_includes_kv_paging_metrics() {
        let m = Metrics::new();
        m.record_kv(&crate::runtime::KvStats {
            pages_in_use: 12,
            pages_shared: 5,
            cow_copies: 2,
            prefix_hit_tokens: 48,
            prefix_miss_tokens: 16,
            ..Default::default()
        });
        let page = NetMetrics::new().render_prometheus(&m.snapshot(), 0);
        assert!(page.contains("speq_kv_pages_allocated 12"));
        assert!(page.contains("speq_kv_pages_shared 5"));
        assert!(page.contains("speq_kv_cow_copies_total 2"));
        assert!(page.contains("speq_prefix_cache_hit_tokens_total 48"));
        assert!(page.contains("speq_prefix_cache_miss_tokens_total 16"));
        assert!(page.contains("speq_prefix_cache_hit_rate 0.75"));
        assert!(page.contains("# TYPE speq_kv_pages_allocated gauge"));
        assert!(page.contains("# TYPE speq_prefix_cache_hit_tokens_total counter"));
    }

    #[test]
    fn exposition_includes_robustness_metrics() {
        let m = Metrics::new();
        m.requests_quarantined.fetch_add(2, Ordering::Relaxed);
        m.degradation_level.store(1, Ordering::Relaxed);
        let page = NetMetrics::new().render_prometheus(&m.snapshot(), 0);
        assert!(page.contains("speq_requests_quarantined_total 2"));
        assert!(page.contains("speq_degradation_level 1"));
        assert!(page.contains("# TYPE speq_degradation_level gauge"));
        // The fault counters are process-global (shared with any other
        // test in this binary that injects), so only assert presence.
        assert!(page.contains("# TYPE speq_faults_injected_total counter"));
        assert!(page.contains("# TYPE speq_faults_recovered_total counter"));
        assert!(page.contains("speq_kv_pages_budget 0"));
    }

    #[test]
    fn exposition_includes_adaptive_speculation_gauges() {
        let m = Metrics::new();
        m.record_spec_adaptive(2, 12.0, 1.5);
        let page = NetMetrics::new().render_prometheus(&m.snapshot(), 0);
        assert!(page.contains("speq_adaptive_sessions 2"));
        assert!(page.contains("speq_adaptive_draft_len 6"));
        assert!(page.contains("speq_adaptive_accept_rate 0.75"));
        assert!(page.contains("# TYPE speq_adaptive_sessions gauge"));
        assert!(page.contains("# TYPE speq_adaptive_draft_len gauge"));
    }
}
