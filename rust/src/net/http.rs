//! Minimal HTTP/1.1 on std sockets: request parsing, response writing,
//! chunked transfer encoding, keep-alive.
//!
//! Scope is exactly what the serving front end needs — `Content-Length`
//! framed request bodies, keep-alive connection reuse, and chunked
//! responses for Server-Sent Events — with hard limits on header and body
//! size so a misbehaving client cannot balloon memory.  Reads are written
//! against a non-blocking/timeout socket: `WouldBlock`/`TimedOut` polls a
//! caller-supplied shutdown flag, which is how connection threads notice a
//! graceful shutdown while parked in a keep-alive read.

use std::io::{ErrorKind, Read, Write};

/// Request head (request line + headers) cap; crossing it is a 431.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;

/// A parsed HTTP/1.1 request.
#[derive(Debug)]
pub struct HttpRequest {
    /// Upper-case method as sent (`GET`, `POST`, ...).
    pub method: String,
    /// Request target, e.g. `/v1/generate` (query strings are not split).
    pub path: String,
    /// Header name/value pairs; names lower-cased at parse time.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Whether the client allows connection reuse (HTTP/1.1 default yes,
    /// `Connection: close` opts out; HTTP/1.0 default no).
    pub keep_alive: bool,
}

impl HttpRequest {
    /// Case-insensitive header lookup (names are stored lower-cased).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Parse/IO failures, each mapping to a response status (or none for raw
/// socket errors, where no response can be delivered).
#[derive(Debug)]
pub enum HttpError {
    /// Malformed request line, headers, or body framing (400).
    Bad(String),
    /// Request head exceeded [`MAX_HEADER_BYTES`] (431).
    HeadersTooLarge,
    /// Declared `Content-Length` exceeded the configured cap (413).
    BodyTooLarge(usize),
    /// Socket error or mid-request disconnect; no response possible.
    Io(std::io::Error),
}

impl HttpError {
    /// The status code to answer with, if a response can still be sent.
    pub fn status(&self) -> Option<u16> {
        match self {
            HttpError::Bad(_) => Some(400),
            HttpError::HeadersTooLarge => Some(431),
            HttpError::BodyTooLarge(_) => Some(413),
            HttpError::Io(_) => None,
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Bad(m) => write!(f, "bad request: {m}"),
            HttpError::HeadersTooLarge => {
                write!(f, "request head exceeds {MAX_HEADER_BYTES} bytes")
            }
            HttpError::BodyTooLarge(n) => write!(f, "request body of {n} bytes exceeds limit"),
            HttpError::Io(e) => write!(f, "socket error: {e}"),
        }
    }
}

/// Read one request off the connection.  `Ok(None)` is a clean end of the
/// connection: the peer closed between requests, or `shutdown()` turned
/// true while no request was in progress.  The caller is expected to have
/// set a short read timeout on the socket so the shutdown flag is polled.
pub fn read_request<R: Read>(
    stream: &mut R,
    max_body: usize,
    shutdown: impl Fn() -> bool,
) -> Result<Option<HttpRequest>, HttpError> {
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 4096];

    // ---- request head: read until the blank line ----
    let head_end = loop {
        if let Some(pos) = find_blank_line(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEADER_BYTES {
            return Err(HttpError::HeadersTooLarge);
        }
        match stream.read(&mut tmp) {
            Ok(0) => {
                return if buf.is_empty() {
                    Ok(None)
                } else {
                    Err(HttpError::Bad("connection closed mid-head".into()))
                }
            }
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                if shutdown() {
                    // Draining: drop idle keep-alive connections; a client
                    // caught mid-send gets the connection closed (the
                    // coordinator is no longer accepting anyway).
                    return Ok(None);
                }
            }
            Err(e) => return Err(HttpError::Io(e)),
        }
    };

    let (method, path, headers, keep_alive) = parse_head(&buf[..head_end])?;

    // ---- body: exactly Content-Length bytes ----
    let content_length = match header_of(&headers, "content-length") {
        Some(v) => v
            .trim()
            .parse::<usize>()
            .map_err(|_| HttpError::Bad(format!("bad content-length {v:?}")))?,
        None => 0,
    };
    if content_length > max_body {
        return Err(HttpError::BodyTooLarge(content_length));
    }
    if header_of(&headers, "transfer-encoding").is_some() {
        return Err(HttpError::Bad("chunked request bodies are not supported".into()));
    }
    let mut body = buf.split_off(head_end + 4);
    while body.len() < content_length {
        // Never read past the declared body: each read is capped at the
        // bytes still owed, so a well-behaved next request on a keep-alive
        // connection stays in the socket for the next `read_request`.
        let need = (content_length - body.len()).min(tmp.len());
        match stream.read(&mut tmp[..need]) {
            Ok(0) => return Err(HttpError::Bad("connection closed mid-body".into())),
            Ok(n) => body.extend_from_slice(&tmp[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                if shutdown() {
                    return Err(HttpError::Bad("connection aborted: server draining".into()));
                }
            }
            Err(e) => return Err(HttpError::Io(e)),
        }
    }
    // The head read can still have pulled pipelined bytes of a *next*
    // request into the buffer; they cannot be replayed, so rather than
    // serve a corrupted follow-up, downgrade the connection to close (the
    // client re-sends on a fresh connection per HTTP semantics).
    let pipelined = body.len() > content_length;
    body.truncate(content_length);

    Ok(Some(HttpRequest { method, path, headers, body, keep_alive: keep_alive && !pipelined }))
}

/// Parse the request line + header block (no trailing blank line).
#[allow(clippy::type_complexity)]
fn parse_head(head: &[u8]) -> Result<(String, String, Vec<(String, String)>, bool), HttpError> {
    let text = std::str::from_utf8(head)
        .map_err(|_| HttpError::Bad("request head is not valid UTF-8".into()))?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() {
        return Err(HttpError::Bad(format!("bad request line {request_line:?}")));
    }
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        _ => return Err(HttpError::Bad(format!("unsupported version {version:?}"))),
    };

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| HttpError::Bad(format!("bad header line {line:?}")))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    let keep_alive = match header_of(&headers, "connection").map(str::to_ascii_lowercase) {
        Some(c) if c == "close" => false,
        Some(c) if c == "keep-alive" => true,
        _ => http11,
    };
    Ok((method, path, headers, keep_alive))
}

fn header_of<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
}

/// Position of the `\r\n\r\n` head terminator, if present.
fn find_blank_line(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Human reason phrase for the statuses the front end emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write a complete `Content-Length`-framed response.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
    extra_headers: &[(&str, &str)],
) -> std::io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", status, status_reason(status))?;
    write!(
        w,
        "content-type: {}\r\ncontent-length: {}\r\nconnection: {}\r\n",
        content_type,
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    )?;
    for (k, v) in extra_headers {
        write!(w, "{k}: {v}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Start a chunked (streaming) response; follow with [`write_chunk`] calls
/// and a final [`finish_chunked`].
pub fn write_chunked_head<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", status, status_reason(status))?;
    write!(
        w,
        "content-type: {}\r\ntransfer-encoding: chunked\r\ncache-control: no-store\r\nconnection: {}\r\n\r\n",
        content_type,
        if keep_alive { "keep-alive" } else { "close" }
    )?;
    w.flush()
}

/// One chunk of a chunked response (empty input is a no-op: a zero-length
/// chunk would terminate the stream).
pub fn write_chunk<W: Write>(w: &mut W, data: &[u8]) -> std::io::Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    write!(w, "{:x}\r\n", data.len())?;
    w.write_all(data)?;
    w.write_all(b"\r\n")?;
    w.flush()
}

/// Terminate a chunked response.
pub fn finish_chunked<W: Write>(w: &mut W) -> std::io::Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn read_one(raw: &[u8]) -> Result<Option<HttpRequest>, HttpError> {
        read_request(&mut Cursor::new(raw.to_vec()), 1024, || false)
    }

    #[test]
    fn parses_a_post_with_body_and_headers() {
        let raw = b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\nContent-Length: 9\r\n\r\n{\"a\":123}";
        let r = read_one(raw).unwrap().unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/v1/generate");
        assert_eq!(r.header("content-type"), Some("application/json"));
        assert_eq!(r.header("CONTENT-TYPE"), Some("application/json"));
        assert_eq!(r.body, b"{\"a\":123}");
        assert!(r.keep_alive, "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn get_without_body_and_connection_close() {
        let raw = b"GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n";
        let r = read_one(raw).unwrap().unwrap();
        assert_eq!(r.method, "GET");
        assert!(r.body.is_empty());
        assert!(!r.keep_alive);
    }

    #[test]
    fn http10_defaults_to_close() {
        let raw = b"GET / HTTP/1.0\r\n\r\n";
        assert!(!read_one(raw).unwrap().unwrap().keep_alive);
    }

    #[test]
    fn clean_eof_is_none() {
        assert!(read_one(b"").unwrap().is_none());
    }

    #[test]
    fn truncated_head_is_bad_request() {
        let e = read_one(b"POST /v1/generate HTTP/1.1\r\nContent-").unwrap_err();
        assert_eq!(e.status(), Some(400));
    }

    #[test]
    fn truncated_body_is_bad_request() {
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort";
        assert_eq!(read_one(raw).unwrap_err().status(), Some(400));
    }

    #[test]
    fn oversized_body_is_413_without_reading_it() {
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: 9999\r\n\r\n";
        match read_one(raw).unwrap_err() {
            HttpError::BodyTooLarge(n) => assert_eq!(n, 9999),
            other => panic!("expected BodyTooLarge, got {other:?}"),
        }
    }

    #[test]
    fn oversized_head_is_431() {
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        raw.extend(std::iter::repeat(b'x').take(MAX_HEADER_BYTES + 16));
        let e = read_request(&mut Cursor::new(raw), 1024, || false).unwrap_err();
        assert_eq!(e.status(), Some(431));
    }

    #[test]
    fn bad_request_line_and_version_are_rejected() {
        assert_eq!(read_one(b"\r\n\r\n").unwrap_err().status(), Some(400));
        assert_eq!(read_one(b"GET / HTTP/2\r\n\r\n").unwrap_err().status(), Some(400));
        assert_eq!(read_one(b"GET / HTTP/1.1\r\nno-colon-here\r\n\r\n").unwrap_err().status(), Some(400));
    }

    #[test]
    fn pipelined_bytes_downgrade_keep_alive_instead_of_corrupting() {
        // Bytes of a second pipelined request pulled in with the first
        // head cannot be replayed — the body must stay exact and the
        // connection must not be reused (no corrupted follow-up parse).
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: 2\r\n\r\nabGET /healthz HTTP/1.1\r\n\r\n";
        let r = read_one(raw).unwrap().unwrap();
        assert_eq!(r.body, b"ab");
        assert!(!r.keep_alive);
    }

    #[test]
    fn response_writer_frames_correctly() {
        let mut out = Vec::new();
        write_response(&mut out, 429, "application/json", b"{}", true, &[("retry-after", "1")])
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("content-length: 2\r\n"));
        assert!(text.contains("retry-after: 1\r\n"));
        assert!(text.contains("connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
    }

    #[test]
    fn chunked_stream_frames_and_terminates() {
        let mut out = Vec::new();
        write_chunked_head(&mut out, 200, "text/event-stream", true).unwrap();
        write_chunk(&mut out, b"hello").unwrap();
        write_chunk(&mut out, b"").unwrap(); // no-op, must not terminate
        write_chunk(&mut out, b"world!").unwrap();
        finish_chunked(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("transfer-encoding: chunked"));
        assert!(text.contains("5\r\nhello\r\n"));
        assert!(text.contains("6\r\nworld!\r\n"));
        assert!(text.ends_with("0\r\n\r\n"));
    }
}
