//! The HTTP serving front end: accept loop, routing, admission control,
//! SSE streaming, and graceful shutdown over the continuous-batching
//! [`coordinator::Server`].
//!
//! Threading model (std-only, mirrors the coordinator's):
//!
//! ```text
//!   TcpListener ──accept──► connection threads (keep-alive loop)
//!        │ (nonblocking poll; shutdown flag)      │
//!        │             parse HTTP/1.1 request ────┤
//!        │                                        ▼
//!        │           admission: Server::try_submit ──Full──► 429 + Retry-After
//!        │                                        │
//!        │              ResponseStream events ◄───┘ (scheduler threads)
//!        │          Chunk* ──► SSE `chunk` events (chunked transfer)
//!        │          Done/Cancelled ──► `done` / `cancelled` / `error`
//! ```
//!
//! Per-request deadlines (`deadline_ms`, or the server-wide default) ride
//! into the scheduler through [`SubmitParams::deadline`]; a client that
//! disconnects mid-stream trips the request's [`CancelToken`], and either
//! way the sequence frees its batch slot between engine steps.  Graceful
//! shutdown stops accepting, drains in-flight sequences via
//! [`Server::drain`], then joins connection threads.
//!
//! [`coordinator::Server`]: crate::coordinator::Server
//! [`SubmitParams::deadline`]: crate::coordinator::SubmitParams
//! [`CancelToken`]: crate::coordinator::CancelToken

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::api::{self, GenerateRequest};
use super::http::{self, HttpRequest};
use super::metrics::NetMetrics;
use crate::coordinator::{
    CancelKind, MetricsSnapshot, QueueError, ResponseEvent, ResponseStream, Server, ServerConfig,
};

/// Front-end configuration on top of the coordinator's [`ServerConfig`].
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Bind address, e.g. `127.0.0.1:8080` (`:0` picks an ephemeral port).
    pub addr: String,
    /// The continuous-batching coordinator under the front end.
    pub server: ServerConfig,
    /// Request body cap; larger declared bodies are answered 413.
    pub max_body_bytes: usize,
    /// Server-wide default deadline applied when a request carries no
    /// `deadline_ms` (`None` = requests may run to completion).
    pub default_deadline: Option<Duration>,
    /// `Retry-After` seconds advertised on 429 responses.
    pub retry_after_s: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:0".to_string(),
            server: ServerConfig::default(),
            max_body_bytes: 256 * 1024,
            default_deadline: None,
            retry_after_s: 1,
        }
    }
}

/// State shared by the accept loop and every connection thread.
struct Shared {
    server: Server,
    net_metrics: NetMetrics,
    shutdown: AtomicBool,
    conns: Mutex<Vec<JoinHandle<()>>>,
    max_body_bytes: usize,
    default_deadline: Option<Duration>,
    retry_after_s: u64,
}

/// A running HTTP serving instance.
pub struct NetServer {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    addr: SocketAddr,
    closed: bool,
}

impl NetServer {
    /// Start the coordinator, bind the listener, and begin accepting.
    pub fn bind(cfg: NetConfig) -> Result<Self> {
        let server = Server::start(cfg.server)?;
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind {}", cfg.addr))?;
        let addr = listener.local_addr().context("local_addr")?;
        // Nonblocking accept so the loop can poll the shutdown flag.
        listener.set_nonblocking(true).context("set_nonblocking")?;
        let shared = Arc::new(Shared {
            server,
            net_metrics: NetMetrics::new(),
            shutdown: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            max_body_bytes: cfg.max_body_bytes,
            default_deadline: cfg.default_deadline,
            retry_after_s: cfg.retry_after_s,
        });
        let sh = shared.clone();
        let accept = std::thread::spawn(move || accept_loop(listener, sh));
        Ok(Self { shared, accept: Some(accept), addr, closed: false })
    }

    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The underlying coordinator (metrics, queue depth) — for tests and
    /// the CLI's shutdown report.
    pub fn coordinator(&self) -> &Server {
        &self.shared.server
    }

    /// Point-in-time coordinator metrics.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.shared.server.metrics().snapshot()
    }

    /// Graceful shutdown: stop accepting connections, drain in-flight
    /// sequences (bounded by `drain_timeout`, see [`Server::drain`]), then
    /// join connection threads.  Returns whether the drain completed
    /// within the timeout; either way every accepted request still reaches
    /// a terminal event before the method returns (generation lengths are
    /// bounded, so this always terminates).  Idempotent.
    pub fn shutdown(&mut self, drain_timeout: Duration) -> bool {
        if self.closed {
            return true;
        }
        self.closed = true;
        self.shared.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let drained = self.shared.server.drain(drain_timeout);
        let conns: Vec<JoinHandle<()>> =
            self.shared.conns.lock().unwrap().drain(..).collect();
        for h in conns {
            let _ = h.join();
        }
        drained
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown(Duration::from_secs(30));
    }
}

fn accept_loop(listener: TcpListener, sh: Arc<Shared>) {
    loop {
        if sh.shutdown.load(Ordering::Relaxed) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                sh.net_metrics.connections.fetch_add(1, Ordering::Relaxed);
                let conn_sh = sh.clone();
                let handle = std::thread::spawn(move || handle_connection(stream, conn_sh));
                let mut conns = sh.conns.lock().unwrap();
                conns.push(handle);
                // Opportunistically reap finished connection threads so a
                // long-lived server does not accumulate handles.
                if conns.len() >= 64 {
                    let (done, live): (Vec<_>, Vec<_>) =
                        conns.drain(..).partition(|h| h.is_finished());
                    *conns = live;
                    drop(conns);
                    for h in done {
                        let _ = h.join();
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Keep-alive loop: parse requests off one connection until it closes,
/// errors, opts out of keep-alive, or the server shuts down.
fn handle_connection(mut stream: TcpStream, sh: Arc<Shared>) {
    // BSD-derived platforms let accepted sockets inherit the listener's
    // O_NONBLOCK; force blocking so the read timeout below governs.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    // Short read timeout: read_request polls the shutdown flag on expiry,
    // which is how idle keep-alive connections notice a graceful shutdown.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    // Bounded writes: a client that stops reading cannot park this thread
    // in write_all forever (which would wedge shutdown's join).
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    loop {
        let req = match http::read_request(&mut stream, sh.max_body_bytes, || {
            sh.shutdown.load(Ordering::Relaxed)
        }) {
            Ok(Some(r)) => r,
            Ok(None) => return,
            Err(e) => {
                if let Some(status) = e.status() {
                    let _ = http::write_response(
                        &mut stream,
                        status,
                        "application/json",
                        api::error_data(&e.to_string()).as_bytes(),
                        false,
                        &[],
                    );
                }
                return;
            }
        };
        sh.net_metrics.http_requests.fetch_add(1, Ordering::Relaxed);
        let keep_alive = req.keep_alive && !sh.shutdown.load(Ordering::Relaxed);
        if route(&mut stream, &req, keep_alive, &sh).is_err() {
            return; // socket gone; any in-flight request was cancelled
        }
        // A route may have shortened the read timeout for disconnect
        // probing; restore the keep-alive polling interval.
        let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
        if !keep_alive {
            return;
        }
    }
}

fn route(
    stream: &mut TcpStream,
    req: &HttpRequest,
    keep_alive: bool,
    sh: &Shared,
) -> std::io::Result<()> {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let body = format!(
                "{{\"status\":\"ok\",\"queue_depth\":{},\"pending\":{}}}",
                sh.server.queue_depth(),
                sh.server.pending_requests()
            );
            http::write_response(
                stream,
                200,
                "application/json",
                body.as_bytes(),
                keep_alive,
                &[],
            )
        }
        ("GET", "/metrics") => {
            let page = sh
                .net_metrics
                .render_prometheus(&sh.server.metrics().snapshot(), sh.server.queue_depth());
            http::write_response(
                stream,
                200,
                "text/plain; version=0.0.4",
                page.as_bytes(),
                keep_alive,
                &[],
            )
        }
        ("POST", "/v1/generate") => handle_generate(stream, req, keep_alive, sh),
        ("POST", "/v1/stream") => handle_stream(stream, req, keep_alive, sh),
        // The path still carries its query string here (`?last=N`), so the
        // match is a prefix guard rather than a literal.
        ("GET", p) if is_trace_path(p) => {
            let body = crate::trace::export_json(trace_last_param(p));
            http::write_response(
                stream,
                200,
                "application/json",
                body.as_bytes(),
                keep_alive,
                &[],
            )
        }
        (_, p) if is_trace_path(p) => http::write_response(
            stream,
            405,
            "application/json",
            api::error_data("method not allowed for this route").as_bytes(),
            keep_alive,
            &[],
        ),
        (_, "/healthz") | (_, "/metrics") | (_, "/v1/generate") | (_, "/v1/stream") => {
            http::write_response(
                stream,
                405,
                "application/json",
                api::error_data("method not allowed for this route").as_bytes(),
                keep_alive,
                &[],
            )
        }
        (_, path) => http::write_response(
            stream,
            404,
            "application/json",
            api::error_data(&format!("no such route {path}")).as_bytes(),
            keep_alive,
            &[],
        ),
    }
}

/// `GET /debug/trace[?last=N]` serves the structured engine trace.
fn is_trace_path(path: &str) -> bool {
    path == "/debug/trace" || path.starts_with("/debug/trace?")
}

/// Events to keep when `?last=N` is absent: two full default rings —
/// enough for a scheduler thread plus the submit-side thread.
const TRACE_DEFAULT_LAST: usize = 65_536;

/// Parse `last=N` out of the `/debug/trace?last=N` query string.
fn trace_last_param(path: &str) -> usize {
    path.split_once('?')
        .and_then(|(_, q)| q.split('&').find_map(|kv| kv.strip_prefix("last=")))
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(TRACE_DEFAULT_LAST)
}

/// Parse the body and run admission control; on rejection the HTTP error
/// has already been written and `Ok(None)` is returned.
fn admit(
    stream: &mut TcpStream,
    req: &HttpRequest,
    keep_alive: bool,
    sh: &Shared,
) -> std::io::Result<Option<(u64, ResponseStream)>> {
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => {
            http::write_response(
                stream,
                400,
                "application/json",
                api::error_data("body is not valid UTF-8").as_bytes(),
                keep_alive,
                &[],
            )?;
            return Ok(None);
        }
    };
    let greq = match GenerateRequest::from_json(text) {
        Ok(g) => g,
        Err(msg) => {
            http::write_response(
                stream,
                400,
                "application/json",
                api::error_data(&msg).as_bytes(),
                keep_alive,
                &[],
            )?;
            return Ok(None);
        }
    };
    // Rung 3 of the degradation ladder: sustained KV pressure sheds new
    // admissions at the front door with `503 + Retry-After` so the
    // in-flight batch can finish and release pages.  Already-admitted
    // requests are unaffected.
    if sh.server.metrics().degradation_level.load(Ordering::Relaxed) >= 3 {
        sh.net_metrics.http_throttled.fetch_add(1, Ordering::Relaxed);
        let retry = sh.retry_after_s.to_string();
        http::write_response(
            stream,
            503,
            "application/json",
            api::error_data("shedding load (kv pressure); retry later").as_bytes(),
            keep_alive,
            &[("retry-after", retry.as_str())],
        )?;
        return Ok(None);
    }
    match sh.server.try_submit(&greq.prompt, greq.submit_params(sh.default_deadline)) {
        Ok(pair) => Ok(Some(pair)),
        Err(QueueError::Full) => {
            // Backpressure: the bounded admission queue is at capacity.
            sh.net_metrics.http_throttled.fetch_add(1, Ordering::Relaxed);
            let retry = sh.retry_after_s.to_string();
            http::write_response(
                stream,
                429,
                "application/json",
                api::error_data("queue full; retry later").as_bytes(),
                keep_alive,
                &[("retry-after", retry.as_str())],
            )?;
            Ok(None)
        }
        Err(QueueError::Closed) => {
            http::write_response(
                stream,
                503,
                "application/json",
                api::error_data("server is shutting down").as_bytes(),
                false,
                &[],
            )?;
            Ok(None)
        }
    }
}

/// Latency bookkeeping shared by both generation routes.
struct LatencyTrack {
    t0: Instant,
    last: Instant,
    ttft: Option<Duration>,
}

impl LatencyTrack {
    fn new() -> Self {
        let now = Instant::now();
        Self { t0: now, last: now, ttft: None }
    }

    /// Record a chunk of `n` tokens against the TTFT / inter-token sinks.
    fn on_chunk(&mut self, n: usize, m: &NetMetrics) {
        let now = Instant::now();
        if self.ttft.is_none() {
            let d = now - self.t0;
            self.ttft = Some(d);
            m.ttft.observe(d.as_secs_f64());
        } else if n > 0 {
            let per_token = (now - self.last).as_secs_f64() / n as f64;
            for _ in 0..n {
                m.inter_token.observe(per_token);
            }
        }
        self.last = now;
    }

    fn finish(&self, m: &NetMetrics) -> Option<f64> {
        m.total.observe(self.t0.elapsed().as_secs_f64());
        self.ttft.map(|d| d.as_secs_f64() * 1e3)
    }
}

/// Probe an idle socket for client disconnect between response events.
/// The client owes no bytes until the response, so `Ok(0)` means it hung
/// up, and early data is unreplayable pipelining — both report
/// `Ok(false)` ("treat as gone", the client retries on a fresh
/// connection).  `Ok(true)` = still connected.  Blocks up to the socket's
/// read timeout (the routes set ~10ms while waiting).
fn client_still_there(stream: &mut TcpStream) -> std::io::Result<bool> {
    use std::io::Read as _;
    let mut probe = [0u8; 1];
    match stream.read(&mut probe) {
        Ok(_) => Ok(false),
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock
                    | std::io::ErrorKind::TimedOut
                    | std::io::ErrorKind::Interrupted
            ) =>
        {
            Ok(true)
        }
        Err(e) => Err(e),
    }
}

/// `POST /v1/generate`: block until the terminal event, answer with one
/// JSON body (TTFT/inter-token are still observed from the chunk stream).
/// Between waits the socket is probed so an aborted client cancels the
/// sequence (freeing its batch slot) instead of running to completion.
fn handle_generate(
    stream: &mut TcpStream,
    req: &HttpRequest,
    keep_alive: bool,
    sh: &Shared,
) -> std::io::Result<()> {
    let (id, resp) = match admit(stream, req, keep_alive, sh)? {
        Some(pair) => pair,
        None => return Ok(()),
    };
    let cancel = resp.cancel_token();
    // Short probe timeout while waiting so the disconnect check adds at
    // most ~10ms to chunk observation (the connection loop restores the
    // keep-alive timeout after this request).
    let _ = stream.set_read_timeout(Some(Duration::from_millis(10)));
    let mut lat = LatencyTrack::new();
    loop {
        match resp.recv_timeout(Duration::from_millis(100)) {
            Ok(None) => match client_still_there(stream) {
                Ok(true) => {}
                Ok(false) => {
                    cancel.cancel();
                    return Ok(());
                }
                Err(e) => {
                    cancel.cancel();
                    return Err(e);
                }
            },
            Ok(Some(r)) => match r.event {
                ResponseEvent::Chunk(c) => lat.on_chunk(c.len(), &sh.net_metrics),
                ResponseEvent::Done(Ok(body)) => {
                    let ttft_ms = lat.finish(&sh.net_metrics);
                    sh.net_metrics.observe_phases(&body.phases);
                    let data =
                        api::done_data(id, &body, ttft_ms, sh.server.metrics().traffic_fields());
                    let w0 = Instant::now();
                    let res = http::write_response(
                        stream,
                        200,
                        "application/json",
                        data.as_bytes(),
                        keep_alive,
                        &[],
                    );
                    sh.net_metrics.phase_sse_write.observe(w0.elapsed().as_secs_f64());
                    return res;
                }
                ResponseEvent::Done(Err(e)) => {
                    lat.finish(&sh.net_metrics);
                    return http::write_response(
                        stream,
                        500,
                        "application/json",
                        api::error_data(&format!("{e:#}")).as_bytes(),
                        keep_alive,
                        &[],
                    );
                }
                ResponseEvent::Cancelled(kind) => {
                    lat.finish(&sh.net_metrics);
                    let status = match kind {
                        CancelKind::Deadline => 504,
                        CancelKind::Cancelled => 503,
                    };
                    return http::write_response(
                        stream,
                        status,
                        "application/json",
                        api::error_data(&kind.to_string()).as_bytes(),
                        keep_alive,
                        &[],
                    );
                }
            },
            Err(_) => {
                return http::write_response(
                    stream,
                    500,
                    "application/json",
                    api::error_data("server dropped the request").as_bytes(),
                    false,
                    &[],
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_binds_ephemeral_localhost() {
        let cfg = NetConfig::default();
        assert_eq!(cfg.addr, "127.0.0.1:0");
        assert!(cfg.max_body_bytes >= 64 * 1024);
        assert_eq!(cfg.retry_after_s, 1);
        assert!(cfg.default_deadline.is_none());
    }

    #[test]
    fn trace_route_matching_and_last_param() {
        assert!(is_trace_path("/debug/trace"));
        assert!(is_trace_path("/debug/trace?last=100"));
        assert!(!is_trace_path("/debug/tracer"));
        assert!(!is_trace_path("/metrics"));
        assert_eq!(trace_last_param("/debug/trace"), TRACE_DEFAULT_LAST);
        assert_eq!(trace_last_param("/debug/trace?last=100"), 100);
        assert_eq!(trace_last_param("/debug/trace?foo=1&last=7"), 7);
        assert_eq!(trace_last_param("/debug/trace?last=bogus"), TRACE_DEFAULT_LAST);
    }
}

/// `POST /v1/stream`: Server-Sent Events over chunked transfer — one
/// `chunk` event per [`ResponseEvent::Chunk`] as the scheduler emits them,
/// then a terminal `done` (with accept-rate/traffic stats), `cancelled`,
/// or `error` event.  A client disconnect trips the request's cancel
/// token so the sequence frees its batch slot between engine steps.
fn handle_stream(
    stream: &mut TcpStream,
    req: &HttpRequest,
    keep_alive: bool,
    sh: &Shared,
) -> std::io::Result<()> {
    let (id, resp) = match admit(stream, req, keep_alive, sh)? {
        Some(pair) => pair,
        None => return Ok(()),
    };
    let cancel = resp.cancel_token();
    if let Err(e) = http::write_chunked_head(stream, 200, "text/event-stream", keep_alive) {
        // Client vanished between admission and the response head: free
        // the batch slot instead of generating into a dead socket.
        cancel.cancel();
        return Err(e);
    }
    // Short probe timeout while waiting for events (see handle_generate);
    // the connection loop restores the keep-alive timeout afterwards.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(10)));
    let mut lat = LatencyTrack::new();
    // Wall time spent writing SSE frames to this client's socket — the
    // net-side attribution bucket (overlaps the scheduler-side phases,
    // so it is reported separately, never summed with them).
    let mut sse_write_s = 0.0f64;
    loop {
        let event = match resp.recv_timeout(Duration::from_millis(100)) {
            Ok(None) => {
                // Nothing streamed yet (queued, or a slow step): a client
                // that already hung up must not occupy a batch slot.
                match client_still_there(stream) {
                    Ok(true) => continue,
                    Ok(false) => {
                        cancel.cancel();
                        return Ok(());
                    }
                    Err(e) => {
                        cancel.cancel();
                        return Err(e);
                    }
                }
            }
            Ok(Some(r)) => r.event,
            Err(_) => {
                let _ = http::write_chunk(
                    stream,
                    &api::sse_event("error", &api::error_data("server dropped the request")),
                );
                return http::finish_chunked(stream);
            }
        };
        match event {
            ResponseEvent::Chunk(c) => {
                lat.on_chunk(c.len(), &sh.net_metrics);
                // Fault site `sock.write`: emulate a congested client
                // (`slow<ms>` delays the chunk write) or a mid-stream
                // connection reset (`reset` hard-closes the socket, which
                // must cancel the sequence like a real disconnect).
                if crate::faults::enabled() {
                    match crate::faults::hit(crate::faults::FaultSite::SockWrite) {
                        Some(crate::faults::FaultAction::Slow(ms)) => {
                            std::thread::sleep(Duration::from_millis(ms));
                        }
                        Some(crate::faults::FaultAction::Reset) => {
                            let _ = stream.shutdown(std::net::Shutdown::Both);
                            cancel.cancel();
                            return Ok(());
                        }
                        _ => {}
                    }
                }
                let ev = api::sse_event("chunk", &api::chunk_event_data(&c));
                let w0 = Instant::now();
                let written = http::write_chunk(stream, &ev);
                sse_write_s += w0.elapsed().as_secs_f64();
                if let Err(e) = written {
                    // Client went away mid-stream: ask the scheduler to
                    // retire the sequence between steps.
                    cancel.cancel();
                    return Err(e);
                }
            }
            ResponseEvent::Done(Ok(body)) => {
                let ttft_ms = lat.finish(&sh.net_metrics);
                sh.net_metrics.observe_phases(&body.phases);
                let data =
                    api::done_data(id, &body, ttft_ms, sh.server.metrics().traffic_fields());
                let w0 = Instant::now();
                let written = http::write_chunk(stream, &api::sse_event("done", &data));
                sse_write_s += w0.elapsed().as_secs_f64();
                sh.net_metrics.phase_sse_write.observe(sse_write_s);
                written?;
                return http::finish_chunked(stream);
            }
            ResponseEvent::Done(Err(e)) => {
                lat.finish(&sh.net_metrics);
                http::write_chunk(
                    stream,
                    &api::sse_event("error", &api::error_data(&format!("{e:#}"))),
                )?;
                return http::finish_chunked(stream);
            }
            ResponseEvent::Cancelled(kind) => {
                lat.finish(&sh.net_metrics);
                let reason = match kind {
                    CancelKind::Deadline => "deadline",
                    CancelKind::Cancelled => "cancelled",
                };
                http::write_chunk(
                    stream,
                    &api::sse_event("cancelled", &format!("{{\"reason\":\"{reason}\"}}")),
                )?;
                return http::finish_chunked(stream);
            }
        }
    }
}
