//! Structured engine tracing: per-request spans and per-step phase events
//! with monotonic timestamps, recorded into fixed-capacity per-thread ring
//! buffers and exported as Chrome trace-event JSON (Perfetto-loadable).
//!
//! The subsystem is always compiled and follows the `faults` discipline:
//! disarmed, every probe is a single relaxed atomic load
//! ([`armed`]) and nothing else runs — no clock reads, no allocation, no
//! locks — so the decode hot path pays one predictable branch.  Armed, an
//! event costs one `Instant` read plus an uncontended per-thread mutex
//! push (the mutex exists only so the exporter can snapshot rings owned
//! by other threads).  Recording never touches RNG state or logits, so
//! token streams are bit-identical armed or disarmed (pinned by
//! `rust/tests/tracing.rs`).
//!
//! Event vocabulary (all timestamps µs since the process trace origin):
//!
//! * **Request spans** (`cat: "req"`, async `ph: b/n/e`, keyed by request
//!   id): `b` at enqueue, an `n` "admit" instant at batch admission, `e`
//!   at the terminal event with an `outcome` arg
//!   (`done`/`cancelled`/`failed`/`quarantined`) and, for completions,
//!   the per-phase latency attribution in seconds.
//! * **Engine phase spans** (`cat: "engine"`, thread-scoped `ph: B/E`):
//!   one span per batched op — `prefill`, `draft` (per sub-step),
//!   `verify`, `ar_decode` — with the participating batch size in `args`.
//! * **Scheduler steps** (`cat: "sched"`, `ph: X`): one complete event
//!   per scheduler loop iteration carrying batch occupancy, drafted /
//!   accepted token counts, weight-byte deltas (from `TrafficCounters`)
//!   and KV page gauges.
//! * **Speculation iterations** (`cat: "spec"`, `ph: i` instants named
//!   `iter`): drafted / accepted / early-exit per draft→verify round —
//!   the accept histogram consumed by `--exp accel-replay`.
//!
//! Ring truncation is inherent (fixed capacity, oldest events drop), so
//! consumers treat an unmatched `E` at the start of a window as a span
//! opened before the capture; `scripts/check_trace.py` encodes exactly
//! that tolerance.

mod export;

pub use export::{export_json, write_file};

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// Events retained per thread; the oldest are overwritten once full.  At
/// ~10 events per engine step this holds several thousand steps — enough
/// for a loadgen run — in a few MiB per recording thread.
pub const RING_CAPACITY: usize = 32_768;

/// Single process-wide arm bit.  Relaxed is sufficient: arming is a mode
/// switch, not a synchronization edge, and a racing probe on another core
/// merely records (or skips) one event at the boundary.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Registry of every thread's ring, for the exporter.  Rings are never
/// removed: a dead thread's tail stays exportable (cheap — capacity is
/// bounded) and tids are never reused.
static REGISTRY: Mutex<Vec<Arc<Mutex<VecDeque<Event>>>>> = Mutex::new(Vec::new());

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// Trace origin: first clock read after process start (or first probe).
static ORIGIN: OnceLock<Instant> = OnceLock::new();

thread_local! {
    static LOCAL: RefCell<Option<(u64, Arc<Mutex<VecDeque<Event>>>)>> =
        const { RefCell::new(None) };
}

/// One event argument value (trace args are flat key→scalar maps).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArgVal {
    Num(f64),
    Str(&'static str),
}

/// One recorded trace event (Chrome trace-event semantics; see the
/// module docs for the vocabulary).
#[derive(Debug, Clone)]
pub struct Event {
    /// Microseconds since the process trace origin.
    pub ts_us: u64,
    /// Duration in µs (complete `X` events only; 0 otherwise).
    pub dur_us: u64,
    /// Chrome phase byte: `B`/`E` (thread span), `X` (complete),
    /// `i` (instant), `b`/`n`/`e` (async span, keyed by `(cat, id)`).
    pub ph: u8,
    pub name: &'static str,
    pub cat: &'static str,
    /// Recording thread (dense ids assigned at first record).
    pub tid: u64,
    /// Async span key (request id); 0 for thread-scoped events.
    pub id: u64,
    pub args: Vec<(&'static str, ArgVal)>,
}

/// Is tracing armed?  The only cost a disarmed probe pays.
#[inline]
pub fn armed() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Arm recording process-wide.
pub fn arm() {
    ORIGIN.get_or_init(Instant::now);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Disarm recording (already-recorded events stay exportable).
pub fn disarm() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Drop every recorded event (rings stay registered).
pub fn clear() {
    let rings = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    for ring in rings.iter() {
        ring.lock().unwrap_or_else(|e| e.into_inner()).clear();
    }
}

/// Arm from the environment: `SPEQ_TRACE=1` (any non-empty value other
/// than `0`) turns recording on at startup.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("SPEQ_TRACE") {
        if !v.is_empty() && v != "0" {
            arm();
        }
    }
}

/// Microseconds since the trace origin (monotonic).
pub fn now_us() -> u64 {
    ORIGIN.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Record one event into the calling thread's ring.  Callers gate on
/// [`armed`] first; this does the ring bookkeeping unconditionally.
fn record(mut ev: Event) {
    LOCAL.with(|l| {
        let mut slot = l.borrow_mut();
        if slot.is_none() {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let ring = Arc::new(Mutex::new(VecDeque::with_capacity(RING_CAPACITY)));
            REGISTRY.lock().unwrap_or_else(|e| e.into_inner()).push(Arc::clone(&ring));
            *slot = Some((tid, ring));
        }
        let (tid, ring) = slot.as_ref().expect("local ring just installed");
        ev.tid = *tid;
        let mut ring = ring.lock().unwrap_or_else(|e| e.into_inner());
        if ring.len() >= RING_CAPACITY {
            ring.pop_front();
        }
        ring.push_back(ev);
    });
}

fn num_args(args: &[(&'static str, f64)]) -> Vec<(&'static str, ArgVal)> {
    args.iter().map(|&(k, v)| (k, ArgVal::Num(if v.is_finite() { v } else { 0.0 }))).collect()
}

/// Thread-scoped span: emits `B` now and `E` when dropped.  Inert when
/// recording was disarmed at construction; if disarming races the span,
/// the `E` is still emitted so recorded rings stay balanced.
pub struct SpanGuard {
    live: bool,
    cat: &'static str,
    name: &'static str,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.live {
            record(Event {
                ts_us: now_us(),
                dur_us: 0,
                ph: b'E',
                name: self.name,
                cat: self.cat,
                tid: 0,
                id: 0,
                args: Vec::new(),
            });
        }
    }
}

/// Open a thread-scoped `B`/`E` span (see [`SpanGuard`]).
pub fn span(cat: &'static str, name: &'static str, args: &[(&'static str, f64)]) -> SpanGuard {
    if !armed() {
        return SpanGuard { live: false, cat, name };
    }
    record(Event {
        ts_us: now_us(),
        dur_us: 0,
        ph: b'B',
        name,
        cat,
        tid: 0,
        id: 0,
        args: num_args(args),
    });
    SpanGuard { live: true, cat, name }
}

/// Thread-scoped instant event (`ph: i`).
pub fn instant(cat: &'static str, name: &'static str, args: &[(&'static str, f64)]) {
    if !armed() {
        return;
    }
    record(Event {
        ts_us: now_us(),
        dur_us: 0,
        ph: b'i',
        name,
        cat,
        tid: 0,
        id: 0,
        args: num_args(args),
    });
}

/// Complete event (`ph: X`) for a window that started at `start_us`
/// (from [`now_us`]).
pub fn complete(
    cat: &'static str,
    name: &'static str,
    start_us: u64,
    args: &[(&'static str, f64)],
) {
    if !armed() {
        return;
    }
    let end = now_us();
    record(Event {
        ts_us: start_us,
        dur_us: end.saturating_sub(start_us),
        ph: b'X',
        name,
        cat,
        tid: 0,
        id: 0,
        args: num_args(args),
    });
}

/// Async request-span begin (`ph: b`, `cat: "req"`), keyed by request id.
pub fn request_begin(id: u64, args: &[(&'static str, f64)]) {
    if !armed() {
        return;
    }
    record(Event {
        ts_us: now_us(),
        dur_us: 0,
        ph: b'b',
        name: "request",
        cat: "req",
        tid: 0,
        id,
        args: num_args(args),
    });
}

/// Async instant inside a request span (`ph: n`), e.g. `admit`.
pub fn request_instant(id: u64, name: &'static str) {
    if !armed() {
        return;
    }
    record(Event {
        ts_us: now_us(),
        dur_us: 0,
        ph: b'n',
        name,
        cat: "req",
        tid: 0,
        id,
        args: Vec::new(),
    });
}

/// Async request-span end (`ph: e`) with a terminal `outcome` arg.
pub fn request_end(id: u64, outcome: &'static str, args: &[(&'static str, f64)]) {
    if !armed() {
        return;
    }
    let mut a = num_args(args);
    a.push(("outcome", ArgVal::Str(outcome)));
    record(Event {
        ts_us: now_us(),
        dur_us: 0,
        ph: b'e',
        name: "request",
        cat: "req",
        tid: 0,
        id,
        args: a,
    });
}

/// Snapshot the newest `last` events across every thread's ring, in
/// timestamp order (stable: same-thread recording order is preserved for
/// equal timestamps).
pub fn snapshot_events(last: usize) -> Vec<Event> {
    let rings: Vec<Arc<Mutex<VecDeque<Event>>>> =
        REGISTRY.lock().unwrap_or_else(|e| e.into_inner()).clone();
    let mut events = Vec::new();
    for ring in rings {
        let ring = ring.lock().unwrap_or_else(|e| e.into_inner());
        events.extend(ring.iter().cloned());
    }
    events.sort_by_key(|e| e.ts_us);
    if events.len() > last {
        events.drain(..events.len() - last);
    }
    events
}

/// Serializes tests (and benches) that arm the process-wide recorder, the
/// same way `faults::test_guard` serializes fault plans.
static TEST_LOCK: Mutex<()> = Mutex::new(());

/// Exclusive tracing session for tests: clears and disarms on acquire
/// and again on drop, so state never leaks across test fns.
pub struct TestGuard {
    _guard: MutexGuard<'static, ()>,
}

impl Drop for TestGuard {
    fn drop(&mut self) {
        disarm();
        clear();
    }
}

pub fn test_guard() -> TestGuard {
    let guard = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    disarm();
    clear();
    TestGuard { _guard: guard }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_probes_record_nothing() {
        let _g = test_guard();
        instant("test", "nothing", &[("x", 1.0)]);
        let _span = span("test", "quiet", &[]);
        drop(_span);
        assert!(!armed());
        let evs = snapshot_events(usize::MAX);
        assert!(
            evs.iter().all(|e| e.cat != "test"),
            "disarmed probes must not record"
        );
    }

    #[test]
    fn spans_balance_and_instants_carry_args() {
        let _g = test_guard();
        arm();
        {
            let _s = span("test", "outer", &[("n", 2.0)]);
            instant("test", "tick", &[("v", 7.0)]);
        }
        disarm();
        let evs: Vec<Event> =
            snapshot_events(usize::MAX).into_iter().filter(|e| e.cat == "test").collect();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].ph, b'B');
        assert_eq!(evs[1].ph, b'i');
        assert_eq!(evs[2].ph, b'E');
        assert!(evs[0].ts_us <= evs[1].ts_us && evs[1].ts_us <= evs[2].ts_us);
        assert_eq!(evs[1].args, vec![("v", ArgVal::Num(7.0))]);
        // All from this thread, so one tid.
        assert!(evs.iter().all(|e| e.tid == evs[0].tid));
    }

    #[test]
    fn request_span_lifecycle_records_outcome() {
        let _g = test_guard();
        // An id no concurrently-running serving test will collide with
        // (arming is process-wide; other threads may record too).
        const ID: u64 = 987_654_321;
        arm();
        request_begin(ID, &[("prompt_len", 8.0)]);
        request_instant(ID, "admit");
        request_end(ID, "done", &[("latency_s", 0.5)]);
        disarm();
        let evs: Vec<Event> =
            snapshot_events(usize::MAX).into_iter().filter(|e| e.id == ID).collect();
        assert_eq!(evs.len(), 3);
        assert_eq!((evs[0].ph, evs[1].ph, evs[2].ph), (b'b', b'n', b'e'));
        assert!(evs[2].args.contains(&("outcome", ArgVal::Str("done"))));
    }

    #[test]
    fn ring_drops_oldest_at_capacity() {
        let _g = test_guard();
        arm();
        for _ in 0..RING_CAPACITY + 10 {
            instant("test", "fill", &[]);
        }
        disarm();
        let evs = snapshot_events(usize::MAX);
        let mine = evs.iter().filter(|e| e.cat == "test").count();
        assert!(mine <= RING_CAPACITY);
        assert!(mine >= RING_CAPACITY - 16, "ring should retain the newest events");
    }

    #[test]
    fn non_finite_args_are_sanitized() {
        let _g = test_guard();
        arm();
        instant("test", "nan", &[("v", f64::NAN), ("w", f64::INFINITY)]);
        disarm();
        let evs: Vec<Event> =
            snapshot_events(usize::MAX).into_iter().filter(|e| e.name == "nan").collect();
        assert_eq!(evs[0].args, vec![("v", ArgVal::Num(0.0)), ("w", ArgVal::Num(0.0))]);
    }

    #[test]
    fn snapshot_last_n_keeps_the_newest() {
        let _g = test_guard();
        arm();
        for _ in 0..8 {
            instant("test", "old", &[]);
        }
        instant("test", "new", &[]);
        disarm();
        // The cap bounds the window size (other test threads may have
        // recorded too, so assert on size and on our own newest event).
        assert!(snapshot_events(3).len() <= 3);
        let mine: Vec<Event> =
            snapshot_events(usize::MAX).into_iter().filter(|e| e.cat == "test").collect();
        assert_eq!(mine.last().expect("recorded events").name, "new");
    }
}
