//! Chrome trace-event JSON export (the `{"traceEvents":[...]}` object
//! format, loadable in Perfetto and chrome://tracing).
//!
//! Rendering is hand-rolled string building: every name/category is a
//! static ASCII identifier and every arg value a sanitized finite number
//! (or static string), so no escaping is required — but the output is
//! still strict JSON, asserted by parsing it back through
//! [`crate::util::json`] in the roundtrip tests.

use std::fmt::Write as _;
use std::path::Path;

use super::{snapshot_events, ArgVal, Event};

/// Render the newest `last` recorded events as a Chrome trace JSON
/// document.
pub fn export_json(last: usize) -> String {
    render(&snapshot_events(last))
}

/// Export the newest `last` events to `path` (the `--trace-out` sink).
pub fn write_file(path: &Path, last: usize) -> std::io::Result<()> {
    std::fs::write(path, export_json(last))
}

fn push_num(out: &mut String, v: f64) {
    // Finite by construction (args are sanitized at record time); render
    // integral values without a fraction, like `util::json::write`.
    if v.fract() == 0.0 && v.abs() < 9e15 {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{v}");
    }
}

/// Render an explicit event list (exporter + tests).
pub fn render(events: &[Event]) -> String {
    let mut out = String::with_capacity(events.len() * 96 + 32);
    out.push_str("{\"traceEvents\":[");
    for (i, ev) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"{}\",\"ts\":{},\"pid\":1,\"tid\":{}",
            ev.name, ev.cat, ev.ph as char, ev.ts_us, ev.tid
        );
        if ev.ph == b'X' {
            let _ = write!(out, ",\"dur\":{}", ev.dur_us);
        }
        if ev.ph == b'i' {
            // Thread-scoped instants.
            out.push_str(",\"s\":\"t\"");
        }
        if matches!(ev.ph, b'b' | b'n' | b'e') {
            let _ = write!(out, ",\"id\":\"{}\"", ev.id);
        }
        if !ev.args.is_empty() {
            out.push_str(",\"args\":{");
            for (j, (k, v)) in ev.args.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                let _ = write!(out, "\"{k}\":");
                match v {
                    ArgVal::Num(n) => push_num(&mut out, *n),
                    ArgVal::Str(s) => {
                        let _ = write!(out, "\"{s}\"");
                    }
                }
            }
            out.push('}');
        }
        out.push('}');
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn ev(ph: u8, name: &'static str, ts: u64) -> Event {
        Event {
            ts_us: ts,
            dur_us: if ph == b'X' { 7 } else { 0 },
            ph,
            name,
            cat: "engine",
            tid: 3,
            id: if matches!(ph, b'b' | b'n' | b'e') { 11 } else { 0 },
            args: vec![("n", ArgVal::Num(4.0))],
        }
    }

    #[test]
    fn rendered_trace_parses_back_as_strict_json() {
        let events = vec![
            ev(b'B', "prefill", 10),
            ev(b'i', "iter", 12),
            ev(b'E', "prefill", 20),
            ev(b'X', "step", 10),
            ev(b'b', "request", 5),
            ev(b'e', "request", 30),
        ];
        let text = render(&events);
        let v = json::parse(&text).expect("exporter must emit strict JSON");
        let arr = v.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 6);
        let first = &arr[0];
        assert_eq!(first.get("name").unwrap().as_str(), Some("prefill"));
        assert_eq!(first.get("ph").unwrap().as_str(), Some("B"));
        assert_eq!(first.get("ts").unwrap().as_f64(), Some(10.0));
        assert_eq!(first.get("tid").unwrap().as_f64(), Some(3.0));
        assert_eq!(first.get("args").unwrap().get("n").unwrap().as_f64(), Some(4.0));
        // X carries dur; instants carry scope; async events carry id.
        assert_eq!(arr[3].get("dur").unwrap().as_f64(), Some(7.0));
        assert_eq!(arr[1].get("s").unwrap().as_str(), Some("t"));
        assert_eq!(arr[4].get("id").unwrap().as_str(), Some("11"));
    }

    #[test]
    fn empty_trace_is_a_valid_document() {
        let v = json::parse(&render(&[])).unwrap();
        assert_eq!(v.get("traceEvents").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn string_args_render_quoted() {
        let mut e = ev(b'e', "request", 9);
        e.args.push(("outcome", ArgVal::Str("done")));
        let v = json::parse(&render(&[e])).unwrap();
        let first = &v.get("traceEvents").unwrap().as_arr().unwrap()[0];
        assert_eq!(first.get("args").unwrap().get("outcome").unwrap().as_str(), Some("done"));
    }
}
