//! Compile-only stub of the `xla` (PJRT) bindings.
//!
//! The SPEQ workspace builds offline and does not ship the XLA native
//! library, so the optional `pjrt` feature links against this stub instead.
//! It reproduces exactly the API surface `speq::runtime` and
//! `speq::model::ModelRuntime` use, with every runtime entry point
//! returning a clear "PJRT unavailable" error.  To execute AOT-compiled
//! HLO for real, point the `xla` path dependency in the workspace
//! `Cargo.toml` at the actual bindings (API-compatible with
//! `xla_extension` 0.5.x) — no `speq` source changes are required.

use std::fmt;
use std::path::Path;

pub type Result<T> = std::result::Result<T, Error>;

/// Error type mirroring the real bindings' error enum (string-backed here).
#[derive(Debug, Clone)]
pub struct Error {
    message: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error {
        message: format!(
            "{what}: PJRT is unavailable in this build (the `pjrt` feature is linked \
             against the compile-only xla stub; swap the `xla` path dependency for the \
             real bindings, or use the default native backend)"
        ),
    }
}

/// Parsed HLO module (stub: never constructed).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        Err(unavailable(&format!("parsing {}", path.as_ref().display())))
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("creating PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling computation"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("uploading host buffer"))
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute over device buffers; one `Vec<PjRtBuffer>` per device.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing computation"))
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn on_device_shape(&self) -> Result<Shape> {
        Err(unavailable("querying device shape"))
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("copying buffer to host"))
    }
}

/// A host-side literal.
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        Err(unavailable("reading literal"))
    }
}

/// Device shapes (array or tuple), as in the real bindings.
#[derive(Debug)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// Array shape: dims as i64, matching the real bindings.
#[derive(Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub client must not construct");
        assert!(err.to_string().contains("PJRT is unavailable"), "{err}");
        let err = HloModuleProto::from_text_file("/tmp/nope.hlo.txt").err().unwrap();
        assert!(err.to_string().contains("PJRT is unavailable"), "{err}");
    }
}
