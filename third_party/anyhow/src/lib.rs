//! Minimal, offline-compatible subset of the `anyhow` crate.
//!
//! The SPEQ build environment has no network access and no vendored crate
//! registry, so the ecosystem `anyhow` is replaced by this in-tree shim.
//! It implements exactly the API surface the workspace uses:
//!
//! * [`Error`] — a context-chain error type (`Display` shows the outermost
//!   message, `{:#}` shows the whole chain joined by `": "`).
//! * [`Result<T>`] — alias with `Error` as the default error type.
//! * [`Context`] — `.context(...)` / `.with_context(...)` on `Result` and
//!   `Option`, including `Result<T, Error>` itself.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros.
//! * A blanket `From<E: std::error::Error + Send + Sync + 'static>` so `?`
//!   converts foreign errors (the source chain is flattened into the
//!   context chain at conversion time; `downcast` is not supported).
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket `From` impl and the
//! `Context` impl for `Result<T, Error>` coherent.

use std::fmt;

/// `Result` with a context-chain [`Error`] default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chain error: an outermost message plus the causes under it.
pub struct Error {
    /// Messages, outermost context first; always non-empty.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Convert a standard error, flattening its source chain.
    fn from_std<E: std::error::Error + ?Sized>(error: &E) -> Self {
        let mut chain = vec![error.to_string()];
        let mut source = error.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Self { chain }
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Self {
        Error::from_std(&error)
    }
}

#[doc(hidden)]
pub mod ext {
    use super::Error;
    use std::fmt;

    /// Unifies foreign `std::error::Error` types and [`Error`] itself so a
    /// single `Context` impl covers both (the real crate's layering).
    pub trait StdError {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            Error::from_std(&self).context(context)
        }
    }

    impl StdError for Error {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// Attach context to errors (`Result`) or missing values (`Option`).
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Err::<(), _>(io_err()).context("reading weights").unwrap_err();
        assert_eq!(format!("{e}"), "reading weights");
        assert_eq!(format!("{e:#}"), "reading weights: file missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "file missing");
    }

    #[test]
    fn context_stacks_on_anyhow_results() {
        let e: Error = Err::<(), _>(anyhow!("root {}", 7))
            .with_context(|| "outer".to_string())
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root 7");
        assert_eq!(e.root_cause(), "root 7");
    }

    #[test]
    fn option_context_reports_missing_values() {
        let v: Option<u32> = None;
        let e = v.context("missing key").unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            bail!("unreachable {}", "spot");
        }
        assert_eq!(format!("{}", f(false).unwrap_err()), "flag was false");
        assert_eq!(format!("{}", f(true).unwrap_err()), "unreachable spot");
        let from_string = anyhow!(String::from("owned message"));
        assert_eq!(format!("{from_string}"), "owned message");
    }

    #[test]
    fn debug_includes_causes() {
        let e: Error = Err::<(), _>(io_err()).context("ctx").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("ctx") && dbg.contains("file missing"), "{dbg}");
    }
}
