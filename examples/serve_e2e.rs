//! END-TO-END driver (EXPERIMENTS.md §E2E): bring up the full serving
//! stack — execution backend, BSFP draft derivation, speculative engine,
//! worker pool, request queue, sessions — and push a realistic mixed
//! workload through it, reporting latency/throughput, accept rates,
//! losslessness, and the simulated SPEQ-accelerator speedup for the
//! measured traces.
//!
//! Works with zero setup: without an artifacts directory the workers run
//! builtin synthetic models on the native backend and the workload uses
//! builtin prompts.
//!
//! Run: cargo run --release --example serve_e2e [-- <requests> <gen_len>]

use anyhow::Result;
use speq::accel::{paper_dims, Accel};
use speq::coordinator::{Mode, ModelSource, Priority, Server, ServerConfig, SubmitParams};
use speq::model::SamplingParams;
use speq::specdec::SpecTrace;
use speq::workload::{load_task_or_builtin, task_names};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(18);
    let gen_len: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(96);
    let model = "llama3.1-8b-tiny";

    let source = ModelSource::auto();
    let manifest = source.manifest()?;
    println!("== SPEQ end-to-end serving driver ==");
    println!(
        "model {model}, {n_requests} requests x {gen_len} tokens, 2 workers ({})\n",
        if manifest.is_some() { "trained artifacts" } else { "builtin zoo, native backend" }
    );

    let server = Server::start(ServerConfig {
        source,
        model: model.into(),
        workers: 2,
        queue_capacity: 64,
        ..ServerConfig::default()
    })?;

    // Mixed workload: all three task families (each loaded once), one
    // multi-turn session, and one autoregressive request as the lossless
    // control.
    let tasks: Vec<_> = task_names()
        .iter()
        .map(|&t| load_task_or_builtin(manifest.as_ref(), t, 64, n_requests.max(1)))
        .collect::<Result<Vec<_>>>()?;
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    let mut control: Option<(Vec<u8>, usize)> = None;
    for i in 0..n_requests {
        let task = task_names()[i % 3];
        let ts = &tasks[i % 3];
        let prompt = ts.prompts[i % ts.prompts.len()].clone();
        let mode = if i == 0 { Mode::Autoregressive } else { Mode::Speculative };
        if i == 1 {
            control = Some((prompt.clone(), gen_len));
        }
        let (id, stream) = server.submit(
            &prompt,
            SubmitParams {
                gen_len,
                mode,
                priority: if i % 3 == 0 { Priority::Interactive } else { Priority::Batch },
                sampling: SamplingParams::greedy(),
                session: if task == "chat" { Some(1000 + (i % 2) as u64) } else { None },
                ..Default::default()
            },
        )?;
        rxs.push((id, task, mode, stream));
    }

    let mut merged = SpecTrace::default();
    let mut spec_tokens_of_control: Option<Vec<u8>> = None;
    for (id, task, mode, stream) in rxs {
        let body = stream.wait()?;
        println!(
            "req {id:>3} [{task:<4}] {:?}  worker {}  {:>4} tok  {:>8.1} ms  r {:.3}",
            mode,
            body.worker,
            body.tokens.len(),
            body.latency_s * 1e3,
            body.trace.accept_rate(),
        );
        if mode == Mode::Speculative {
            merged.merge(&body.trace);
            if spec_tokens_of_control.is_none() {
                spec_tokens_of_control = Some(body.tokens.clone());
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // Lossless control: re-run the same prompt autoregressively.
    if let (Some((prompt, glen)), Some(spec_out)) = (control, spec_tokens_of_control) {
        let (_, stream) = server.submit(
            &prompt,
            SubmitParams { gen_len: glen, mode: Mode::Autoregressive, ..Default::default() },
        )?;
        let ar_out = stream.wait()?.tokens;
        println!(
            "\nlossless control: speculative output {} autoregressive",
            if ar_out == spec_out { "== (IDENTICAL to)" } else { "!= (MISMATCH vs)" }
        );
        assert_eq!(ar_out, spec_out);
    }

    let snap = server.metrics().snapshot();
    println!("\n== serving summary ==");
    println!(
        "completed {} | tokens {} | throughput {:.1} tok/s (CPU testbed)",
        snap.completed, snap.tokens, snap.tokens as f64 / wall
    );
    println!(
        "latency p50 {:.0} ms | p95 {:.0} ms | p99 {:.0} ms",
        snap.latency_p50_ms, snap.latency_p95_ms, snap.latency_p99_ms
    );
    println!(
        "batch occupancy mean {:.2} seqs/step | failed {} | sustained {:.1} tok/s",
        snap.batch_occupancy_mean, snap.failed, snap.tokens_per_s
    );
    println!(
        "engine: {} draft steps, {} verify passes, accept rate {:.3}, L-bar {:.2}",
        merged.draft_steps(), merged.verify_passes(), merged.accept_rate(),
        merged.mean_draft_len()
    );

    // Replay the aggregate measured trace on the simulated accelerator at
    // the paper-scale geometry — this is the paper's headline number.
    let dims = paper_dims(model).unwrap();
    let tc = Accel::default().run_trace(dims, &merged, 1024);
    println!("\n== simulated SPEQ accelerator ({} @ paper dims) ==", dims.name);
    println!(
        "speedup vs FP16 autoregressive: {:.2}x (paper: ~2.0x) | energy gain {:.2}x (paper: 1.74x)",
        tc.speedup(), tc.energy_efficiency_gain()
    );
    server.shutdown();
    Ok(())
}
