//! Inspect BSFP quantization on a real weight tensor: exponent histogram
//! (Fig. 2c), bit-sharing layout, remap statistics, and the lossless
//! reconstruction property — the paper's §III walked end to end.
//!
//! Runs on the builtin zoo with zero setup (trained artifacts are used
//! automatically when present).
//! Run: cargo run --release --example quantize_inspect [-- <model> <tensor>]

use anyhow::Result;
use speq::bsfp::{exponent_histogram, f32_to_f16_bits, quantize_tensor, REMAP_FLAG};
use speq::runtime::{load_backend, Backend, ModelSource};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model_name = args.first().map(String::as_str).unwrap_or("llama2-7b-tiny");
    let tensor = args.get(1).map(String::as_str).unwrap_or("layer0.w_down");

    let backend = load_backend(&ModelSource::auto(), model_name)?;
    let model = backend.as_ref();
    let shape = model
        .weights()
        .shapes
        .get(tensor)
        .ok_or_else(|| anyhow::anyhow!("tensor {tensor:?} not in model {model_name:?}"))?
        .clone();
    anyhow::ensure!(shape.len() == 2, "tensor {tensor:?} is not a 2-D linear");
    let w = model.weights().f32(tensor);
    println!("{model_name} / {tensor}: shape {shape:?} ({} backend)", model.backend_name());

    // Fig. 2(c): the exponent histogram.
    let hist = exponent_histogram(w.iter().copied());
    println!("\nFP16 exponent histogram (biased):");
    let max = *hist.iter().max().unwrap() as f64;
    for (e, &c) in hist.iter().enumerate() {
        if c > 0 {
            let bar = "#".repeat((c as f64 / max * 48.0).ceil() as usize);
            println!("  e={e:>2} {c:>8} {bar}");
        }
    }
    let wasted: u64 = hist[16..].iter().sum();
    println!("exponents >= 16: {wasted}  (the wasted bit the paper reclaims)");

    // Quantize and report the remap statistics.
    let (k, n) = (shape[0], shape[1]);
    let qt = quantize_tensor(w, k, n);
    let flagged = qt
        .w_r
        .iter()
        .filter(|&&r| (r >> 11) & 1 == 1)
        .count();
    println!(
        "\nBSFP: tensor_scale {} | {} of {} weights flagged (remapped bits)",
        qt.tensor_scale,
        flagged,
        qt.w_q.len()
    );
    let remap_rate_expected: f64 = {
        // Expected flag rate from the exponent histogram and Fig. 3.
        let total: u64 = hist[..16].iter().sum();
        let f: u64 = hist[..16]
            .iter()
            .enumerate()
            .filter(|(e, _)| REMAP_FLAG[*e] == 1)
            .map(|(_, &c)| c)
            .sum();
        f as f64 / total as f64
    };
    println!(
        "flag rate {:.4} (predicted from histogram: {:.4})",
        flagged as f64 / qt.w_q.len() as f64,
        remap_rate_expected
    );

    // Lossless property.  (The canonical FP16 bits of a packed linear live
    // in the bit-plane store itself, so re-derive the expected bits from
    // the f32 expansion — it is exactly the FP16 widening of those bits.)
    let rec = qt.reconstruct_fp16_bits();
    let orig: Vec<u16> = w.iter().map(|&v| f32_to_f16_bits(v)).collect();
    assert_eq!(rec, orig, "lossless reconstruction failed");
    println!("lossless: W_q ∥ W_r reconstructs the FP16 weights bit-exactly");

    // Draft error statistics.
    println!("draft MSE vs FP16: {:.3e}", qt.draft_mse());
    Ok(())
}
