//! Explore the SPEQ accelerator design space: how speedup responds to DRAM
//! bandwidth, draft accept rate, and array size — the co-design story of
//! §IV beyond the paper's single design point.
//!
//! Run: cargo run --release --example accel_explore

use speq::accel::{paper_dims, Accel, AccelConfig, EnergyParams};
use speq::specdec::{IterRecord, SpecTrace};

fn trace_with_rate(r: f64, l: u32, iters: usize) -> SpecTrace {
    // Deterministic trace whose accept pattern realizes rate ~r.
    let mut iterations = Vec::new();
    let mut acc = 0.0;
    for _ in 0..iters {
        acc += r * l as f64;
        let accepted = acc.min(l as f64) as u32;
        acc -= accepted as f64;
        iterations.push(IterRecord { drafted: l, accepted, early_exit: false });
    }
    let produced = iterations.iter().map(|i| i.accepted as usize + 1).sum();
    SpecTrace { iterations, produced, prompt_len: 1024 }
}

fn main() {
    let dims = paper_dims("Llama2-7b").unwrap();

    println!("== speedup vs accept rate (L = 16, paper design point) ==");
    let accel = Accel::default();
    for r in [0.5, 0.7, 0.8, 0.9, 0.95, 0.976, 1.0] {
        let t = trace_with_rate(r, 16, 32);
        let tc = accel.run_trace(dims, &t, 1024);
        println!(
            "  r = {r:<5}  speedup {:>5.2}x   energy gain {:>5.2}x",
            tc.speedup(),
            tc.energy_efficiency_gain()
        );
    }

    println!("\n== speedup vs DRAM bandwidth (r = 0.95) ==");
    for gbps in [12.8, 25.6, 51.2, 102.4] {
        let cfg = AccelConfig { dram_bytes_per_s: gbps * 1e9, ..Default::default() };
        let a = Accel::new(cfg, EnergyParams::default());
        let t = trace_with_rate(0.95, 16, 32);
        let tc = a.run_trace(dims, &t, 1024);
        println!(
            "  {gbps:>6.1} GB/s  AR {:>7.1} ms/tok  SPEQ speedup {:>5.2}x",
            tc.ar.time_s(&a.cfg) * 1e3 / tc.tokens as f64,
            tc.speedup()
        );
    }

    println!("\n== speedup vs PE array size (r = 0.95, 25.6 GB/s) ==");
    for dim in [16usize, 32, 64] {
        let cfg = AccelConfig { pe_rows: dim, pe_cols: dim, ..Default::default() };
        let a = Accel::new(cfg, EnergyParams::default());
        let t = trace_with_rate(0.95, 16, 32);
        let tc = a.run_trace(dims, &t, 1024);
        println!("  {dim:>2}x{dim:<2} PEs   speedup {:>5.2}x", tc.speedup());
    }
    println!("\n(decode is DRAM-bound: array size barely moves the needle — the");
    println!(" win comes from shrinking the weight stream, which is BSFP's job)");
}
