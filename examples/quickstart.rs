//! Quickstart: load a model, BSFP-quantize it (implicitly, from its own
//! bits), and generate with speculative decoding.
//!
//! Run after `make artifacts && cargo build --release`:
//!     cargo run --release --example quickstart

use anyhow::Result;
use speq::model::{Manifest, ModelRuntime, SamplingParams};
use speq::runtime::Runtime;
use speq::specdec::{Engine, SpecConfig};

fn main() -> Result<()> {
    // 1. Load the artifacts manifest ($SPEQ_ARTIFACTS or ./artifacts).
    let manifest = Manifest::load(Manifest::default_root())?;
    println!("models available: {:?}", manifest.model_names());

    // 2. Bring up the PJRT CPU runtime and one model. Loading compiles the
    //    five AOT graphs and derives the BSFP draft weights from the FP16
    //    bits — no second model, no training (the paper's core claim).
    let rt = Runtime::cpu()?;
    let model = ModelRuntime::load(&rt, &manifest, "vicuna-7b-tiny")?;
    println!(
        "loaded {} ({} params, draft shares all of them)",
        model.entry.config.name, model.entry.config.param_count
    );

    // 3. Generate speculatively (greedy).
    let engine = Engine::new(&model);
    let prompt = b"Q: grace has 6 cups and buys 5 more. how many cups now?\nA: ";
    let cfg = SpecConfig { gen_len: 96, ..Default::default() };
    let spec = engine.generate_spec(prompt, &cfg)?;
    println!("\n--- output ---\n{}", String::from_utf8_lossy(&spec.tokens));
    println!(
        "accept rate {:.3} | mean draft len {:.2} | {} verify passes for {} tokens",
        spec.trace.accept_rate(),
        spec.trace.mean_draft_len(),
        spec.trace.verify_passes(),
        spec.trace.produced
    );

    // 4. Losslessness: identical to plain full-precision decoding.
    let ar = engine.generate_ar(prompt, 96, SamplingParams::greedy())?;
    assert_eq!(ar.tokens, spec.tokens, "speculative output must be lossless");
    println!("lossless: speculative == autoregressive, token for token");
    Ok(())
}
