//! Quickstart: load a model, BSFP-quantize it (implicitly, from its own
//! bits), and generate with speculative decoding.
//!
//! Works with zero setup — no artifacts, no XLA:
//!     cargo run --release --example quickstart
//! With trained artifacts (`make artifacts`) the same code picks them up
//! automatically.

use anyhow::Result;
use speq::model::SamplingParams;
use speq::runtime::{load_backend, Backend, ModelSource};
use speq::specdec::{Engine, SpecConfig};

fn main() -> Result<()> {
    // 1. Pick a model source: ./artifacts (or $SPEQ_ARTIFACTS) when a
    //    manifest exists, else the builtin synthetic zoo.
    let source = ModelSource::auto();
    match &source {
        ModelSource::Artifacts(p) => println!("using trained artifacts at {}", p.display()),
        ModelSource::Builtin => println!("no artifacts found — using the builtin synthetic zoo"),
    }

    // 2. Load one model. The BSFP draft weights are derived from the FP16
    //    bits of the target's own parameters — no second model, no training
    //    (the paper's core claim).
    let backend = load_backend(&source, "vicuna-7b-tiny")?;
    let model = backend.as_ref();
    println!(
        "loaded {} on the {} backend ({} params, draft shares all of them)",
        model.config().name,
        model.backend_name(),
        model.config().param_count
    );

    // 3. Generate speculatively (greedy).
    let engine = Engine::new(model);
    let prompt = b"Q: grace has 6 cups and buys 5 more. how many cups now?\nA: ";
    let cfg = SpecConfig { gen_len: 96, ..Default::default() };
    let spec = engine.generate_spec(prompt, &cfg)?;
    println!("\n--- output ---\n{}", String::from_utf8_lossy(&spec.tokens));
    println!(
        "accept rate {:.3} | mean draft len {:.2} | {} verify passes for {} tokens",
        spec.trace.accept_rate(),
        spec.trace.mean_draft_len(),
        spec.trace.verify_passes(),
        spec.trace.produced
    );

    // 4. Losslessness: identical to plain full-precision decoding.
    let ar = engine.generate_ar(prompt, 96, SamplingParams::greedy())?;
    assert_eq!(ar.tokens, spec.tokens, "speculative output must be lossless");
    println!("lossless: speculative == autoregressive, token for token");
    Ok(())
}
