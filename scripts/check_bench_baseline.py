#!/usr/bin/env python3
"""Diff a loadgen BENCH_server_*.json snapshot against its checked-in baseline.

Usage:
    check_bench_baseline.py <baseline.json> <current.json> [<current.json> ...]

Each file holds one JSON object in the loadgen ``bench_json`` schema
(``tokens_per_sec``, ``ttft_p95_ms``, ``scenario``, ...).  When several
current files are given (CI passes a glob), the first one that parses is
used.

The tolerance band is deliberately wide: shared CI runners jitter by
integer factors, so the gate only catches order-of-magnitude regressions:

* ``tokens_per_sec`` must stay >= ``MIN_THROUGHPUT_RATIO`` x baseline;
* ``ttft_p95_ms``    must stay <= ``MAX_TTFT_RATIO``       x baseline;
* the scenario tags must match, and the run must have completed requests.

Exit status 0 = within band, 1 = regression or malformed input.
"""

import json
import sys

MIN_THROUGHPUT_RATIO = 0.25  # current tokens/sec may drop to 1/4 of baseline
MAX_TTFT_RATIO = 8.0         # current p95 TTFT may grow to 8x baseline


def load_one(path):
    with open(path, "r", encoding="utf-8") as fh:
        text = fh.read().strip()
    # CI artifacts are one BENCH_JSON object per line; take the first.
    first_line = text.splitlines()[0] if text else ""
    return json.loads(first_line)


def first_parseable(paths):
    errors = []
    for path in paths:
        try:
            return path, load_one(path)
        except (OSError, ValueError, IndexError) as exc:
            errors.append(f"{path}: {exc}")
    raise SystemExit("no parseable current snapshot:\n  " + "\n  ".join(errors))


def main(argv):
    if len(argv) < 3:
        raise SystemExit(__doc__)
    baseline_path, current_paths = argv[1], argv[2:]
    baseline = load_one(baseline_path)
    current_path, current = first_parseable(current_paths)

    failures = []

    base_scenario = baseline.get("scenario")
    cur_scenario = current.get("scenario")
    if base_scenario != cur_scenario:
        failures.append(
            f"scenario mismatch: baseline={base_scenario!r} current={cur_scenario!r}"
        )

    if current.get("completed", 0) <= 0:
        failures.append("current run completed zero requests")

    base_tps = float(baseline.get("tokens_per_sec", 0.0))
    cur_tps = float(current.get("tokens_per_sec", 0.0))
    tps_floor = MIN_THROUGHPUT_RATIO * base_tps
    if base_tps > 0.0 and cur_tps < tps_floor:
        failures.append(
            f"tokens_per_sec {cur_tps:.1f} below floor {tps_floor:.1f} "
            f"({MIN_THROUGHPUT_RATIO}x baseline {base_tps:.1f})"
        )

    base_ttft = float(baseline.get("ttft_p95_ms", 0.0))
    cur_ttft = float(current.get("ttft_p95_ms", 0.0))
    ttft_ceiling = MAX_TTFT_RATIO * base_ttft
    if base_ttft > 0.0 and cur_ttft > ttft_ceiling:
        failures.append(
            f"ttft_p95_ms {cur_ttft:.1f} above ceiling {ttft_ceiling:.1f} "
            f"({MAX_TTFT_RATIO}x baseline {base_ttft:.1f})"
        )

    print(f"baseline: {baseline_path} (scenario={base_scenario})")
    print(f"current:  {current_path} (scenario={cur_scenario})")
    print(
        f"tokens_per_sec: {cur_tps:.1f} vs baseline {base_tps:.1f} "
        f"(floor {tps_floor:.1f})"
    )
    print(
        f"ttft_p95_ms:    {cur_ttft:.1f} vs baseline {base_ttft:.1f} "
        f"(ceiling {ttft_ceiling:.1f})"
    )

    if failures:
        for f in failures:
            print(f"FAIL: {f}", file=sys.stderr)
        return 1
    print("OK: within tolerance band")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
