#!/usr/bin/env python3
"""Validate a SPEQ Chrome trace-event JSON export (Perfetto-loadable).

Usage:
    check_trace.py <trace.json> [--require-cats cat1,cat2,...]

Checks, in order:

* the document parses as strict JSON and holds a ``traceEvents`` array
  of objects with the mandatory Chrome trace fields (``name``, ``cat``,
  ``ph``, ``ts``, ``pid``, ``tid``);
* per-thread timestamps are monotonically non-decreasing;
* thread-scoped ``B``/``E`` spans balance LIFO by name.  The recorder
  uses fixed-capacity rings, so a window may begin mid-span: unmatched
  ``E`` events *before the first ``B`` on that thread* are tolerated
  (and counted), but any other mismatch fails;
* async request spans (``ph`` in ``b``/``n``/``e``, keyed by ``id``)
  are ordered begin -> instants -> end per key, with the same
  truncation tolerance for keys whose ``b`` predates the window;
* ``e`` request events carry an ``outcome`` arg;
* every category named via ``--require-cats`` appears at least once
  (the CI serving smoke requires ``req,engine,sched,spec``).

Exit status 0 = valid, 1 = malformed or inconsistent.
"""

import json
import sys

MANDATORY_FIELDS = ("name", "cat", "ph", "ts", "pid", "tid")


def fail(msg):
    print(f"FAIL: {msg}", file=sys.stderr)
    return 1


def main(argv):
    if len(argv) < 2:
        raise SystemExit(__doc__)
    path = argv[1]
    require_cats = []
    if len(argv) >= 4 and argv[2] == "--require-cats":
        require_cats = [c for c in argv[3].split(",") if c]

    with open(path, "r", encoding="utf-8") as fh:
        try:
            doc = json.load(fh)
        except ValueError as exc:
            return fail(f"{path}: not valid JSON: {exc}")

    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return fail(f"{path}: no traceEvents array")
    if not events:
        return fail(f"{path}: traceEvents is empty")

    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            return fail(f"event {i} is not an object")
        for field in MANDATORY_FIELDS:
            if field not in ev:
                return fail(f"event {i} ({ev.get('name')!r}) missing {field!r}")

    # Per-thread timestamp monotonicity + LIFO span balance.  The export
    # is globally ts-sorted with same-thread order preserved, so walking
    # in file order per tid is walking in record order.
    last_ts = {}
    stacks = {}
    truncated_e = 0
    for i, ev in enumerate(events):
        tid = ev["tid"]
        ts = ev["ts"]
        if ts < last_ts.get(tid, 0):
            return fail(f"event {i}: ts {ts} regressed on tid {tid}")
        last_ts[tid] = ts
        ph = ev["ph"]
        if ph == "B":
            stacks.setdefault(tid, []).append(ev["name"])
        elif ph == "E":
            stack = stacks.get(tid, [])
            if stack:
                top = stack.pop()
                if top != ev["name"]:
                    return fail(
                        f"event {i}: E {ev['name']!r} closes B {top!r} on tid {tid}"
                    )
            else:
                # An empty-stack E can only close a span whose B fell off
                # the front of the bounded ring — tolerated and counted.
                truncated_e += 1
    # Spans still open at the end are a live capture racing an in-flight
    # step (e.g. /debug/trace mid-generation) — warn, don't fail.
    unclosed = {t: s for t, s in stacks.items() if s}
    if unclosed:
        print(f"note: spans open at end of window (live capture): {unclosed}")

    # Async request lifecycles: b before n/e, e terminal, outcome present.
    state = {}
    truncated_async = 0
    for i, ev in enumerate(events):
        ph = ev["ph"]
        if ph not in ("b", "n", "e"):
            continue
        if "id" not in ev:
            return fail(f"event {i}: async {ph!r} without id")
        key = (ev["cat"], ev["id"])
        cur = state.get(key)
        if ph == "b":
            if cur == "open":
                return fail(f"event {i}: duplicate b for request {key}")
            state[key] = "open"
        elif ph == "n":
            if cur is None:
                truncated_async += 1
                state[key] = "open"
            elif cur == "closed":
                return fail(f"event {i}: n after e for request {key}")
        else:  # "e"
            if cur is None:
                truncated_async += 1
            elif cur == "closed":
                return fail(f"event {i}: duplicate e for request {key}")
            if "outcome" not in ev.get("args", {}):
                return fail(f"event {i}: request e without outcome arg ({key})")
            state[key] = "closed"

    cats = {ev["cat"] for ev in events}
    missing = [c for c in require_cats if c not in cats]
    if missing:
        return fail(f"required categories absent: {missing} (have {sorted(cats)})")

    outcomes = {}
    for ev in events:
        if ev["ph"] == "e":
            o = ev.get("args", {}).get("outcome", "?")
            outcomes[o] = outcomes.get(o, 0) + 1
    print(
        f"OK: {len(events)} events, {len(last_ts)} threads, "
        f"{sum(1 for e in events if e['ph'] == 'B')} spans, "
        f"request outcomes {outcomes or '{}'}, "
        f"truncated: {truncated_e} span E / {truncated_async} async"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
